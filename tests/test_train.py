"""Training integration: SAFE-aggregated training on an 8-device mesh.

Checks (in a subprocess): loss decreases, SAFE == INSEC within fixed-point
tolerance, failover mid-training, FedAvg weighted rounds, the manual
expert-parallel MoE path vs the dense MoE path, and the cross-plane
acceptance of ISSUE 3: a wire-trained FedAvg round (real local steps per
learner, deltas chunk-streamed through the asyncio broker) publishes a
model delta bit-identical to the in-SPMD ``train/federated.py`` round."""
import pytest

from helpers import partial_manual_supported, run_multidevice


@pytest.mark.skipif(not partial_manual_supported(), reason=
    "partial-manual shard_map (manual data + auto model) unsupported "
    "by this jax/XLA SPMD partitioner — see ARCHITECTURE.md")
def test_safe_training_matches_insec():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.core import make_aggregator
from repro.train.train_step import make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("internlm2-1.8b")
model = Model(cfg)
toks = np.random.RandomState(0).randint(0, cfg.vocab, (4, 2, 64)).astype(np.int32)

def run(mode, steps=4):
    agg = make_aggregator(mode, 4, axis="data")
    b = make_train_step(model, agg, mesh, lr=1e-3)
    s = b.init_state_fn(model.init(jax.random.key(0)))
    ls = []
    for i in range(steps):
        s, m = b.step_fn(s, jnp.asarray(toks), counter=i * b.padded_size * 4)
        ls.append(float(m["loss"]))
    return ls

safe = run("safe")
insec = run("insec")
assert safe[-1] < safe[0], f"loss not decreasing: {safe}"
assert max(abs(a - b) for a, b in zip(safe, insec)) < 5e-3, (safe, insec)
print("SAFE_TRAIN_OK")
""", devices=8)
    assert "SAFE_TRAIN_OK" in out


@pytest.mark.skipif(not partial_manual_supported(), reason=
    "partial-manual shard_map (manual data + auto model) unsupported "
    "by this jax/XLA SPMD partitioner — see ARCHITECTURE.md")
def test_training_with_learner_failure():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.core import make_aggregator
from repro.train.train_step import make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("internlm2-1.8b")
model = Model(cfg)
agg = make_aggregator("safe", 4, axis="data")
b = make_train_step(model, agg, mesh, lr=1e-3)
s = b.init_state_fn(model.init(jax.random.key(0)))
toks = np.random.RandomState(0).randint(0, cfg.vocab, (4, 2, 64)).astype(np.int32)
alive = jnp.array([1., 1., 0., 1.])  # learner 2 dead (progress failover)
losses = []
for i in range(4):
    s, m = b.step_fn(s, jnp.asarray(toks), counter=i * b.padded_size * 4,
                     alive=alive)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0] and np.isfinite(losses).all()
# initiator failure: rank 0 dead
alive0 = jnp.array([0., 1., 1., 1.])
s, m = b.step_fn(s, jnp.asarray(toks), counter=10 * b.padded_size * 4,
                 alive=alive0)
assert np.isfinite(float(m["loss"]))
print("FAILOVER_TRAIN_OK")
""", devices=8)
    assert "FAILOVER_TRAIN_OK" in out


@pytest.mark.skipif(not partial_manual_supported(), reason=
    "partial-manual shard_map (manual data + auto model) unsupported "
    "by this jax/XLA SPMD partitioner — see ARCHITECTURE.md")
def test_federated_weighted_rounds():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.core import make_aggregator
from repro.train.federated import make_federated_round

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("internlm2-1.8b")
model = Model(cfg)
agg = make_aggregator("safe", 4, axis="data", weighted=True)
b = make_federated_round(model, agg, mesh, local_steps=2, local_lr=1e-3)
params = model.init(jax.random.key(0))
toks = np.random.RandomState(0).randint(0, cfg.vocab, (4, 2, 2, 64)).astype(np.int32)
w = jnp.array([1000., 2000., 1500., 500.])
losses = []
for r in range(3):
    params, m = b.round_fn(params, jnp.asarray(toks), weights=w,
                           counter=r * 50_000_000)
    losses.append(float(m["local_loss"]))
assert losses[-1] < losses[0], losses
print("FED_OK")
""", devices=8)
    assert "FED_OK" in out


WIRE_FED_CODE = """
import asyncio
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.core import make_aggregator
from repro.core.machines import key_derivations
from repro.train.federated import make_federated_round, make_wire_federated
from repro.train.flatten import tree_to_flat
from repro.net import SafeBroker, run_federated_rounds_net

n = {n}
R = {rounds}
mesh = jax.make_mesh((n,), ("data",))  # fully manual: works on every jax
cfg = get_smoke_config("internlm2-1.8b")
model = Model(cfg)
agg = make_aggregator("safe", n, axis="data", weighted=True)
b = make_federated_round(model, agg, mesh, local_steps=2, local_lr=1e-3,
                         return_delta=True)
rng = np.random.RandomState(0)
toks = rng.randint(0, cfg.vocab, (n, 2, 2, 64)).astype(np.int32)
w = (1000.0 * (1.0 + np.arange(n))).astype(np.float32)  # private org sizes

wf = make_wire_federated(model, dict((i + 1, toks[i]) for i in range(n)),
                         local_steps=2, local_lr=1e-3)
W = wf.words_per_round(weighted=True)  # counter stride both planes share

# in-SPMD reference: R rounds, counter advancing W words per round
p_spmd = model.init(jax.random.key(0))
spmd_deltas = []
for r in range(R):
    p_spmd, m = b.round_fn(p_spmd, jnp.asarray(toks),
                           weights=jnp.asarray(w), counter=r * W)
    spmd_deltas.append(np.asarray(m["avg_delta"]))

# wire plane: same seeds, real local steps per learner, the SAME R
# rounds on ONE persistent broker session — deltas chunk-streamed
# through the hop-level streaming combine (P ~ 1.7M words, 256k-word
# chunks), reset_round + RoundCursor between rounds
params = model.init(jax.random.key(0))  # round_fn donated the first tree

async def go():
    broker = SafeBroker(progress_timeout=0.5, monitor_interval=0.1,
                        aggregation_timeout=60.0)
    addr = await broker.start()
    try:
        d0 = key_derivations()
        out = await run_federated_rounds_net(
            params, wf.local_fns, wf.apply_fn, addr, rounds=R, weights=w,
            words_per_round=W, chunk_words=1 << 18)
        return out, key_derivations() - d0
    finally:
        await broker.stop()

(new_params, results), derivs = asyncio.run(go())
assert len(results) == R
for r, res in enumerate(results):
    assert res.stats["aggregation_total"] == 4 * n, (r, res.stats)
    assert res.stats["chunk_frames_in"] > 0, "chunk streaming did not engage"
    assert res.streamed_combines == n - 1, (r, res.streamed_combines)
    assert np.array_equal(spmd_deltas[r], res.average), (
        f"round (r) wire-trained delta diverged from the in-SPMD round")
assert np.array_equal(np.asarray(tree_to_flat(p_spmd)),
                      np.asarray(tree_to_flat(new_params)))
# Round-0 amortization: derivations for R rounds == one round's worth
# (4 per LearnerCrypto + the pair keys each learner's hops touch)
assert derivs <= n * 7, derivs
print("WIRE_FED_BITIDENT_OK")
"""


@pytest.mark.parametrize("n,rounds", [(4, 2), (8, 2)])
def test_wire_round_delta_bit_identical(n, rounds):
    """ISSUE 3/4 acceptance: same seeds ⇒ the wire-trained rounds'
    published model deltas (learners running real local FedAvg steps,
    deltas streamed through the chunk-granular combine over TCP, R
    rounds on ONE persistent broker session with no key re-derivation
    after Round 0) are bit-identical to the in-SPMD
    ``train/federated.py`` rounds — and the §5 message counts hold per
    round. (timeout: R rounds of n-learner local jits + the SPMD loop
    in one subprocess — 2x the default budget so a loaded 2-core box
    doesn't flake the suite; the run itself is ~1 min idle.)"""
    out = run_multidevice(WIRE_FED_CODE.format(n=n, rounds=rounds),
                          devices=n, timeout=1800)
    assert "WIRE_FED_BITIDENT_OK" in out


@pytest.mark.skipif(not partial_manual_supported(), reason=
    "partial-manual shard_map (manual data + auto model) unsupported "
    "by this jax/XLA SPMD partitioner — see ARCHITECTURE.md")
def test_expert_parallel_moe_matches_dense():
    # f32: in bf16 a freshly-initialized router has near-uniform probs, so
    # 1-ulp accumulation differences between batch tilings legitimately
    # flip top-k picks (inherent capacity-MoE numerics) — the structural
    # equivalence of the EP dataflow is what this test pins down.
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_config
from repro.models import Model
from jax.sharding import PartitionSpec as P
from repro.train.flatten import is_expert_path, _path_str

cfg = dataclasses.replace(get_smoke_config("qwen3-moe-235b-a22b"),
                          dtype="float32")
mesh = jax.make_mesh((4, 2), ("data", "model"))
model_dense = Model(cfg)
params = model_dense.init(jax.random.key(0))
toks = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab, (4, 32))
                   .astype(np.int32))
dense_logits, _ = jax.jit(model_dense.forward)(params, toks)

# manual-EP path: experts sharded over the 4 'data' ranks
cfg_ep = dataclasses.replace(cfg, ep_axis="data", ep_ranks=4)
model_ep = Model(cfg_ep)
specs = jax.tree_util.tree_map_with_path(
    lambda p, x: P(None, "data") if is_expert_path(_path_str(p)) else P(),
    params)

def per_rank(prm, t):
    t = t.reshape(t.shape[1:])
    logits, _ = model_ep.forward(prm, t)
    return logits

f = jax.shard_map(per_rank, mesh=mesh, in_specs=(specs, P("data")),
                  out_specs=P("data"), axis_names=frozenset({"data"}),
                  check_vma=False)
with jax.set_mesh(mesh):
    ep_logits = jax.jit(f)(params, toks[:, None])
err = float(jnp.max(jnp.abs(ep_logits.reshape(dense_logits.shape)
                            - dense_logits)))
scale = float(jnp.max(jnp.abs(dense_logits)))
assert err / scale < 1e-4, f"EP vs dense rel err {err/scale}"
print("EP_MOE_OK")
""", devices=8)
    assert "EP_MOE_OK" in out
