"""Property tests for the fault plane (ISSUE 8).

The reproducibility contract of ``repro.net.faults`` — "fault draws are
seeded per (seed, node), so a learner's fault plan is reproducible
regardless of asyncio interleaving" — as executable properties:

  * same (seed, params) ⇒ byte-identical latency/drop schedules from
    independently constructed interceptors, for any interleaving of
    per-node streams (hypothesis; the container falls back to the
    deterministic stub in tests/_hypothesis_fallback.py);
  * the schedule survives process boundaries: a child interpreter with
    the same seed produces the same digest (so a sharded/multi-process
    load harness replays identical fault plans);
  * the heavy-tail interceptor's empirical percentiles sit within
    declared tolerance of its analytic ``declared_percentile`` contract
    — the numbers WAN benchmark rows annotate are the numbers the code
    actually draws from.
"""
import hashlib
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.net.faults import (
    WAN_PROFILES,
    Chain,
    DropInterceptor,
    HeavyTailLatencyInterceptor,
    LatencyInterceptor,
    make_wan_interceptor,
)

seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _schedule(icpt, nodes=(1, 2, 5), per_node=32) -> np.ndarray:
    """Draw each node's stream in node-major order."""
    return np.array([[icpt._draw(n) for _ in range(per_node)]
                     for n in nodes])


class TestDeterminism:
    @settings(max_examples=20, deadline=None)
    @given(seeds, st.floats(min_value=1e-4, max_value=0.5))
    def test_latency_schedule_identical(self, seed, mean):
        a = LatencyInterceptor(mean=mean, floor=mean / 2, seed=seed)
        b = LatencyInterceptor(mean=mean, floor=mean / 2, seed=seed)
        assert np.array_equal(_schedule(a), _schedule(b))

    @settings(max_examples=20, deadline=None)
    @given(seeds, st.floats(min_value=1e-3, max_value=0.3),
           st.floats(min_value=0.1, max_value=2.0))
    def test_heavy_tail_schedule_identical(self, seed, median, sigma):
        a = HeavyTailLatencyInterceptor(median=median, sigma=sigma, seed=seed)
        b = HeavyTailLatencyInterceptor(median=median, sigma=sigma, seed=seed)
        assert np.array_equal(_schedule(a), _schedule(b))

    @settings(max_examples=20, deadline=None)
    @given(seeds)
    def test_interleaving_does_not_change_a_node_stream(self, seed):
        """Node 3's k-th draw is the same whether other nodes drew in
        between or not — per-node streams are independent, which is
        exactly what makes schedules asyncio-interleaving-proof."""
        alone = LatencyInterceptor(mean=0.01, seed=seed)
        solo = [alone._draw(3) for _ in range(16)]
        mixed = LatencyInterceptor(mean=0.01, seed=seed)
        interleaved = []
        for k in range(16):
            mixed._draw(1)
            interleaved.append(mixed._draw(3))
            mixed._draw(7)
        assert solo == interleaved

    @settings(max_examples=20, deadline=None)
    @given(seeds, st.floats(min_value=0.01, max_value=0.5))
    def test_drop_schedule_identical(self, seed, p):
        def plan(icpt):
            out = []
            for node in (1, 4):
                rng = icpt._rngs.setdefault(
                    node, np.random.RandomState((icpt.seed * 1_000_003
                                                 + node) % 2**31))
                out.append([bool(rng.uniform() < icpt.p)
                            for _ in range(64)])
            return out

        assert (plan(DropInterceptor(p=p, seed=seed))
                == plan(DropInterceptor(p=p, seed=seed)))

    def test_schedule_identical_across_processes(self):
        """A child interpreter with the same seed digests to the same
        schedule — multi-process load harnesses replay fault plans."""
        code = (
            "import hashlib, numpy as np\n"
            "from repro.net.faults import (HeavyTailLatencyInterceptor,\n"
            "                              LatencyInterceptor)\n"
            "def sched(icpt):\n"
            "    return np.array([[icpt._draw(n) for _ in range(32)]\n"
            "                     for n in (1, 2, 5)])\n"
            "d = hashlib.sha256()\n"
            "d.update(sched(LatencyInterceptor(mean=0.02, seed=99)))\n"
            "d.update(sched(HeavyTailLatencyInterceptor(\n"
            "    median=0.05, sigma=0.8, seed=99)))\n"
            "print(d.hexdigest())\n"
        )
        import repro

        src = os.path.dirname(os.path.dirname(os.path.abspath(
            repro.__file__)))
        child = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=120, env=dict(os.environ, PYTHONPATH=src))
        assert child.returncode == 0, child.stderr
        here = hashlib.sha256()
        here.update(_schedule(LatencyInterceptor(mean=0.02, seed=99)))
        here.update(_schedule(HeavyTailLatencyInterceptor(
            median=0.05, sigma=0.8, seed=99)))
        assert child.stdout.strip() == here.hexdigest()


class TestHeavyTailPercentiles:
    @pytest.mark.parametrize("median,sigma", [(0.05, 0.8), (0.1, 0.4)])
    def test_empirical_matches_declared(self, median, sigma):
        icpt = HeavyTailLatencyInterceptor(median=median, sigma=sigma,
                                           seed=7)
        draws = np.array([icpt._draw(1) for _ in range(20000)])
        # sampling tolerance at 20k draws: tight at the median, looser
        # out in the tail (p99 has ~200 effective samples)
        for q, tol in ((50.0, 0.05), (90.0, 0.10), (99.0, 0.25)):
            declared = icpt.declared_percentile(q)
            empirical = float(np.percentile(draws, q))
            assert abs(empirical - declared) <= tol * declared, (
                q, declared, empirical)

    def test_declared_percentiles_are_closed_form(self):
        icpt = HeavyTailLatencyInterceptor(median=0.1, sigma=0.8,
                                           floor=0.01)
        assert icpt.declared_percentile(50) == pytest.approx(0.11)
        assert icpt.declared_percentile(99) == pytest.approx(
            0.01 + 0.1 * float(np.exp(0.8 * icpt.Z99)))
        with pytest.raises(ValueError):
            icpt.declared_percentile(95)

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            HeavyTailLatencyInterceptor(median=0.0)
        with pytest.raises(ValueError):
            HeavyTailLatencyInterceptor(median=0.1, sigma=-1.0)


class TestWanProfiles:
    def test_registry_spans_the_paper_range(self):
        rtts = sorted(m["rtt_ms"] for m in WAN_PROFILES.values())
        assert len(WAN_PROFILES) >= 2
        assert rtts[0] <= 10.0 and rtts[-1] >= 200.0
        assert any(m["kind"] == "lognormal" for m in WAN_PROFILES.values())
        assert any(m["loss"] > 0 for m in WAN_PROFILES.values())

    def test_factory_builds_declared_shape(self):
        for name, meta in WAN_PROFILES.items():
            icpt = make_wan_interceptor(name, seed=3)
            parts = icpt.parts if isinstance(icpt, Chain) else (icpt,)
            lat = parts[0]
            if meta["kind"] == "lognormal":
                assert isinstance(lat, HeavyTailLatencyInterceptor)
                # one-way median at rtt/2
                assert lat.median == pytest.approx(meta["rtt_ms"] / 2e3)
            else:
                assert isinstance(lat, LatencyInterceptor)
                # mean one-way delay (floor + Exp mean) at rtt/2
                assert lat.floor + lat.mean == pytest.approx(
                    meta["rtt_ms"] / 2e3)
            if meta["loss"] > 0:
                assert isinstance(parts[1], DropInterceptor)
                assert parts[1].p == meta["loss"]
            else:
                assert len(parts) == 1

    def test_unknown_profile_raises(self):
        with pytest.raises(ValueError, match="unknown WAN profile"):
            make_wan_interceptor("dialup")

    def test_same_seed_same_plan_through_factory(self):
        a = make_wan_interceptor("intercontinental_tail", seed=11)
        b = make_wan_interceptor("intercontinental_tail", seed=11)
        assert np.array_equal(_schedule(a.parts[0]), _schedule(b.parts[0]))
