"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
persists JSON to results/benchmarks/. See DESIGN.md §9 for the
figure-to-module index.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (constrained, device_aggregation, failover,
                            feature_scalability, hierarchical, kernel_bench,
                            messages, node_scalability, subgrouping)
    print("name,us_per_call,derived")
    t0 = time.time()
    mods = [
        ("node_scalability (Figs 6-9)", node_scalability.main),
        ("feature_scalability (Figs 10-12)", feature_scalability.main),
        ("failover (Figs 13-14)", failover.main),
        ("constrained deep-edge (Figs 15-18)", constrained.main),
        ("subgrouping (Figs 19-20)", subgrouping.main),
        ("hierarchical federation (§5.10)", hierarchical.main),
        ("messages (§5 formulas)", messages.main),
        ("device_aggregation", device_aggregation.main),
        ("kernel_bench", kernel_bench.main),
    ]
    failures = 0
    for name, fn in mods:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# FAILED {name}: {e!r}", flush=True)
    print(f"# done in {time.time()-t0:.1f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
