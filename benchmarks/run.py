"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit)
and persists JSON to results/benchmarks/. With ``--bench-json`` each
module additionally writes a stable ``BENCH_<module>.json``
(schema ``safe-bench/v1`` — see common.save_bench_json) so the perf
trajectory is machine-readable across runs. ``--only NAME`` restricts to
modules whose key contains NAME. See DESIGN.md §9 for the
figure-to-module index.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--bench-json", action="store_true",
                        help="emit stable BENCH_<module>.json per module")
    parser.add_argument("--only", default=None,
                        help="run only modules whose key contains this")
    args = parser.parse_args()

    from benchmarks import (bon_wire, common, constrained, device_aggregation,
                            failover, feature_scalability, hierarchical,
                            kernel_bench, messages, multi_session, net_load,
                            node_scalability, paper_scale, slo, streaming,
                            subgrouping)
    print("name,us_per_call,derived")
    t0 = time.time()
    mods = [
        ("node_scalability", "node_scalability (Figs 6-9)", node_scalability.main),
        ("feature_scalability", "feature_scalability (Figs 10-12)", feature_scalability.main),
        ("failover", "failover (Figs 13-14)", failover.main),
        ("constrained", "constrained deep-edge (Figs 15-18)", constrained.main),
        ("subgrouping", "subgrouping (Figs 19-20)", subgrouping.main),
        ("hierarchical", "hierarchical federation (§5.10)", hierarchical.main),
        ("messages", "messages (§5 formulas)", messages.main),
        ("device_aggregation", "device_aggregation", device_aggregation.main),
        ("kernel_bench", "kernel_bench", kernel_bench.main),
        ("multi_session", "multi_session engine (ARCHITECTURE.md)", multi_session.main),
        ("net_load", "net_load wire-plane broker + shard scaling "
         "(repro/net, ISSUE 6)", net_load.main),
        ("paper_scale", "paper_scale n=36/n=128 wire runs vs BON (§6.1; "
         "SAFE_PAPER_N512=1 adds n=512)", paper_scale.main),
        ("streaming", "streaming combine + persistent sessions (§8 wire)",
         streaming.main),
        ("slo", "SLO-gated multi-tenant load + admission control "
         "(repro/obs, ISSUE 7)", slo.main),
        ("bon_wire", "bon_wire SAFE-vs-BON bake-off + WAN-calibrated "
         "cost model (§6.1; ISSUE 8)", bon_wire.main),
    ]
    failures = 0
    matched = 0
    for key, name, fn in mods:
        if args.only and args.only not in key:
            continue
        matched += 1
        print(f"# --- {name} ---", flush=True)
        before = len(common.rows())
        mod_t0 = time.time()
        status = "ok"
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            status = "failed"
            print(f"# FAILED {name}: {e!r}", flush=True)
        if args.bench_json:
            common.save_bench_json(key, common.rows()[before:], status,
                                   time.time() - mod_t0)
    if args.only and matched == 0:
        keys = ", ".join(k for k, _, _ in mods)
        print(f"# ERROR: --only {args.only!r} matched no module "
              f"(available: {keys})", file=sys.stderr)
        sys.exit(2)
    print(f"# done in {time.time()-t0:.1f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
