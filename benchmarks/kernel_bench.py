"""Pallas kernel benchmark: fused masking vs unfused reference.

On this CPU container the kernels execute in interpret mode, so wall
time is NOT TPU-predictive. The roofline-relevant derived numbers are
static: HBM bytes per element for the fused kernel vs the unfused op
sequence, and the VPU op count of the Threefry schedule. Wall time of
the jnp oracle is reported as the correctness-path cost only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, wall
from repro.kernels.ref import chain_combine_ref, mask_add_ref
from repro.crypto.prf import keystream_pair_lanes

V = 1 << 22  # 4M elements (a ~16 MB gradient chunk)


def run() -> dict:
    x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, V)
                    .astype(np.float32))
    cipher = jnp.asarray(np.random.RandomState(1)
                         .randint(0, 2**32, V, dtype=np.uint64)
                         .astype(np.uint32))
    key = jnp.array([1, 2], jnp.uint32)
    kin = jnp.array([3, 4], jnp.uint32)

    ref_mask = jax.jit(lambda: mask_add_ref(x, key, 0))
    jax.block_until_ready(ref_mask())
    t_oracle = wall(lambda: jax.block_until_ready(ref_mask()))

    ref_chain = jax.jit(lambda: chain_combine_ref(cipher, x, kin, key, 0))
    jax.block_until_ready(ref_chain())
    t_chain = wall(lambda: jax.block_until_ready(ref_chain()))

    # HBM traffic per element (TPU):
    #   unfused mask_add: pad write+read (8) + x read (4) + out write (4) = 16 B
    #   fused kernel:     x read (4) + out write (4)                     =  8 B
    #   unfused chain hop: 2 pads (16) + cipher r/w (8) + x (4) + out (4)= 32 B
    #   fused chain hop:  cipher (4) + x (4) + out (4)                   = 12 B
    payload = {
        "elements": V,
        "mask_add": {"oracle_wall_s": t_oracle,
                     "bytes_per_elem_fused": 8,
                     "bytes_per_elem_unfused": 16,
                     "hbm_traffic_reduction": 2.0},
        "chain_combine": {"oracle_wall_s": t_chain,
                          "bytes_per_elem_fused": 12,
                          "bytes_per_elem_unfused": 32,
                          "hbm_traffic_reduction": 32 / 12},
        # Threefry-2x32: 20 rounds x ~6 uint32 VPU ops / 2 lanes
        "prf_vpu_ops_per_word": 60,
    }
    emit("kernel/mask_add", t_oracle * 1e6,
         f"fused 8B/elem vs 16B/elem unfused (2.0x HBM)")
    emit("kernel/chain_combine", t_chain * 1e6,
         f"fused 12B/elem vs 32B/elem unfused (2.7x HBM)")
    # projected TPU v5e time for one fused hop over a 100M-param vector
    v5e_bw = 819e9
    t_hop = 100e6 * 12 / v5e_bw
    emit("kernel/projected_v5e_hop_100M", t_hop * 1e6,
         "memory-bound @819GB/s")
    payload["projected_v5e_hop_100M_s"] = t_hop
    save_json("kernel_bench", payload)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()
