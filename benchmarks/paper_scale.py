"""Paper-scale wire-plane runs: n=36, with and without failover.

The paper's headline (§6.1, abstract): at 36 nodes SAFE outperforms
state-of-the-art secure aggregation (Bonawitz-style pairwise masking,
``core/bon_protocol.py``) by 70x with failover and 56x without. This
module drives that scale through the REAL transport — 36 learners, 36
TCP connections, the asyncio broker of ``repro/net`` — and pairs it
with the BON baseline at the same n:

  * ``wire_n36`` / ``wire_n36_f3`` — one SAFE round over TCP, clean and
    with nodes 4–6 dead (the paper's failover experiment). The §5
    closed forms (4n and 4(n−f)+2f) are *asserted* inside
    :func:`repro.net.loadgen.run_paper_scale`, so a run that completes
    has already validated its message counts.
  * ``wire_n36_chunked`` — the same round with V=65536 deltas streamed
    through the chunked transfer plane (docs/PROTOCOL.md §6), pricing
    multi-frame transfers at scale.
  * ``sim_safe_n36*`` / ``sim_bon_n36*`` — the discrete-event SAFE sim
    and the BON baseline on the same EDGE cost model, whose virtual-time
    ratio is the reproduction of the paper's 70x/56x-flavoured claim
    (message ratio is exact; wall time on localhost TCP is not
    latency-faithful, so the cost model carries the time axis).

Beyond the paper's 36 (ISSUE 6): ``wire_n128*`` rows run the same
assertions at n=128 — clean, with nodes 4–6 dead, under *mid-round*
churn (a learner crashes between consuming and reposting the
aggregate, the worst §5.4 case), and against a 2-shard
:class:`~repro.net.shard.ShardedBroker` fleet. Every row is checked
bit-identical to the discrete-event sim in-harness
(``bit_identical=True`` inside ``run_paper_scale``), so sim↔wire
equivalence is pinned at paper-plus scale, not just test-sized n.
``SAFE_PAPER_N512=1`` adds an n=512 row (thousands of sockets —
``ensure_fd_headroom`` lifts RLIMIT_NOFILE or fails loudly);
``SAFE_SMOKE=1`` keeps only the n=36 rows for CI-sized runs.

Measured numbers and the regeneration command live in EXPERIMENTS.md
§Paper-scale. Rows land in the standard CSV/JSON harness; a standalone
run (``python -m benchmarks.paper_scale``) also writes
``BENCH_paper_scale.json`` (schema ``safe-bench/v1``).
"""
from __future__ import annotations

import asyncio
import os

import numpy as np

from benchmarks.common import emit, save_json, standalone_bench

N = 36
N_BIG = 128
FAILED = (4, 5, 6)  # the paper takes out nodes 4-6 after key exchange
SMOKE = bool(os.environ.get("SAFE_SMOKE"))
WANT_N512 = bool(os.environ.get("SAFE_PAPER_N512"))


def _emit_wire(key: str, row: dict) -> None:
    shard = f" shards={row['shards']}" if row.get("shards", 1) > 1 else ""
    churn = " churn" if row.get("churn") else ""
    emit(f"paper_scale/{key}", row["wall_s"] * 1e6,
         f"msgs={row['messages']} (closed form "
         f"{row['expected_messages']}{churn}) "
         f"reposts={row['monitor_reposts']} "
         f"bytes={row['bytes_sent']} "
         f"chunks={row['chunk_frames_in']}/{row['chunk_frames_out']}"
         f"{shard} bit_identical={row['bit_identical']}")


def run() -> dict:
    from repro.core.bon_protocol import run_bon_round
    from repro.core.protocol import run_safe_round
    from repro.net.loadgen import run_paper_scale

    out: dict = {}

    # ---- wire plane (real TCP) ----------------------------------------
    out["wire_n36"] = asyncio.run(run_paper_scale(n=N, V=256))
    out["wire_n36_f3"] = asyncio.run(
        run_paper_scale(n=N, V=256, failures=FAILED))
    out["wire_n36_chunked"] = asyncio.run(
        run_paper_scale(n=N, V=65536, chunk_words=16384))
    for key in ("wire_n36", "wire_n36_f3", "wire_n36_chunked"):
        _emit_wire(key, out[key])

    # ---- beyond the paper: n=128 (ISSUE 6), n=512 behind a flag -------
    if not SMOKE:
        # generous §5.3 monitor timeouts: at 128 sequential hops on a
        # loaded box a *live* slow hop must not look dead, or a spurious
        # repost perturbs the closed-form count the row asserts
        big_kw = dict(progress_timeout=2.0, monitor_interval=0.5)
        out["wire_n128"] = asyncio.run(
            run_paper_scale(n=N_BIG, V=256, **big_kw))
        out["wire_n128_f3"] = asyncio.run(
            run_paper_scale(n=N_BIG, V=256, failures=FAILED, **big_kw))
        # node 5 dies mid-round, between consuming and reposting the
        # running aggregate — §5.4 re-election at scale; message total
        # is only floor-bounded under churn (see run_paper_scale)
        out["wire_n128_churn"] = asyncio.run(run_paper_scale(
            n=N_BIG, V=256, churn={5: 1}, progress_timeout=1.0,
            monitor_interval=0.25, aggregation_timeout=8.0))
        out["wire_n128_shards2"] = asyncio.run(run_paper_scale(
            n=N_BIG, V=256, failures=FAILED, shards=2, **big_kw))
        for key in ("wire_n128", "wire_n128_f3", "wire_n128_churn",
                    "wire_n128_shards2"):
            _emit_wire(key, out[key])
    if WANT_N512 and not SMOKE:
        out["wire_n512_f3"] = asyncio.run(
            run_paper_scale(n=512, V=256, failures=FAILED,
                            progress_timeout=5.0, monitor_interval=1.0,
                            aggregation_timeout=300.0))
        _emit_wire("wire_n512_f3", out["wire_n512_f3"])

    # ---- cost-model baselines at the same n ---------------------------
    rng = np.random.RandomState(0)
    vals = rng.uniform(-1, 1, (N, 256)).astype(np.float32)
    safe = run_safe_round(vals)
    safe_f = run_safe_round(vals, failed_nodes=list(FAILED))
    bon = run_bon_round(vals)
    bon_f = run_bon_round(vals, failed_nodes=list(FAILED))
    for key, r in (("sim_safe_n36", safe), ("sim_safe_n36_f3", safe_f)):
        out[key] = {"virtual_s": r.virtual_time,
                    "messages": r.stats.aggregation_total,
                    "bytes": r.bytes_sent}
        emit(f"paper_scale/{key}", r.virtual_time * 1e6,
             f"msgs={r.stats.aggregation_total} bytes={r.bytes_sent}")
    for key, r in (("sim_bon_n36", bon), ("sim_bon_n36_f3", bon_f)):
        out[key] = {"virtual_s": r.virtual_time, "messages": r.messages,
                    "bytes": r.bytes_sent,
                    "shares_created": r.shares_created}
        emit(f"paper_scale/{key}", r.virtual_time * 1e6,
             f"msgs={r.messages} bytes={r.bytes_sent} "
             f"shares={r.shares_created}")

    # the paper's comparison axes: BON/SAFE time ratio on the shared
    # EDGE cost model, and the exact message ratio. Asymmetric but
    # conservative in SAFE's favour: BON's dropout wait is excluded
    # (global_timeout=0 — the subtracted form of Fig. 14) while SAFE's
    # failover time still *includes* its §5.3 discovery timeouts, so
    # time_failover is a lower bound on the advantage.
    out["ratios"] = {
        "time_clean": bon.virtual_time / safe.virtual_time,
        "time_failover": bon_f.virtual_time / safe_f.virtual_time,
        "messages_clean": bon.messages / safe.stats.aggregation_total,
        "messages_failover": bon_f.messages / safe_f.stats.aggregation_total,
    }
    emit("paper_scale/bon_over_safe", out["ratios"]["time_clean"] * 1e6,
         f"time x{out['ratios']['time_clean']:.1f} clean, "
         f"x{out['ratios']['time_failover']:.1f} failover; "
         f"msgs x{out['ratios']['messages_clean']:.1f}/"
         f"x{out['ratios']['messages_failover']:.1f}")
    save_json("paper_scale", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("paper_scale", run)
