"""Streaming-combine + persistent-session ablation (ISSUE 4).

The §8 pipelining argument is SAFE's wall-clock case: crypto and
transfer of a split model overlap along the chain. This module prices
the two wire-plane halves of that claim against each other and against
PR 3's baseline:

  * **reassemble-then-combine vs. streaming combine**, one round: the
    buffered path (``stream=False`` — each learner downloads every
    chunk, reassembles, decrypts/adds/encrypts whole, re-uploads) vs.
    the chunk-granular combine (chunk k decrypted/added/re-encrypted
    and shipped downstream while chunk k+1 is in flight).
  * **per-round session rebuild vs. persistent multi-round sessions**,
    R rounds: PR 3's ``run_safe_round_net`` loop (create_session + n
    TCP connects + full key derivation *per round*) vs. ONE
    :class:`~repro.net.client.PersistentNetSession` (reset_round +
    RoundCursor counter bases between rounds; no key re-derivation
    after Round 0 — asserted here via ``machines.key_derivations()``).
  * **prefetch depth** {1, 2, 4}: the in-flight get_chunk budget whose
    winner is wire.DEFAULT_PREFETCH_DEPTH.
  * **auto path selection** (ISSUE 6): ``stream=None`` picks streamed
    vs. buffered by payload size (``wire.MIN_STREAM_WORDS``); asserted
    here that the fallback engages below the threshold and the chosen
    path is never slower than buffered beyond wall-clock noise — at
    EVERY n, including the n=4 smoke point where fixed sub-threshold
    chunking used to cost x0.81 (ISSUE 9: frame-sized payloads now skip
    the chunk plane and its per-chunk consume handshakes wholesale).
  * **cross-round pipelining** (ISSUE 9, §11): rebuild vs persistent vs
    ``pipelined`` (window-2 ``run_rounds_pipelined``) rounds/s — round
    r+1's chunk streams upload while round r's tail drains, proven by
    ``chunk_frames_future > 0`` on the broker, with per-round 4n closed
    forms and bit-identity intact. Wall-clock wins are cpu-gated on
    bare localhost (1 core serializes both legs; ``host_cpus`` rides in
    the payload) and demonstrated under a 10 ms-RTT WAN profile, where
    rounds are latency-bound and overlap pays even single-core.

Bit-exactness is asserted in-harness at every n: the streamed, the
buffered, and every persistent round's published average must equal the
discrete-event sim's bitwise (rows only emit after the check passes;
the ``streaming/bit_equal`` row records it machine-readably for CI).

``SAFE_SMOKE=1`` shrinks n/V/R for CI. Standalone
(``python -m benchmarks.streaming``) writes ``BENCH_streaming.json``
(schema ``safe-bench/v1``). Measured numbers: EXPERIMENTS.md §Streaming.
"""
from __future__ import annotations

import asyncio
import os
import time

import numpy as np

from benchmarks.common import emit, save_json, standalone_bench

SMOKE = bool(os.environ.get("SAFE_SMOKE"))
HOST_CPUS = os.cpu_count() or 1
NS = (4, 8) if SMOKE else (8, 36)
V = 4096 if SMOKE else 65536
CHUNK = 512 if SMOKE else 8192
R = 3 if SMOKE else 5
DEPTHS = (1, 2, 4)
BROKER_KW = dict(progress_timeout=2.0, monitor_interval=0.5,
                 aggregation_timeout=120.0)


async def _one_round(vals, *, stream, prefetch_depth=None,
                     chunk_words=None):
    from repro.net import SafeBroker, run_safe_round_net

    broker = SafeBroker(**BROKER_KW)
    addr = await broker.start()
    try:
        return await run_safe_round_net(
            vals, addr, chunk_words=chunk_words or CHUNK, stream=stream,
            prefetch_depth=prefetch_depth)
    finally:
        await broker.stop()


async def _rebuild_rounds(addr, rounds_vals, *, stream):
    """PR 3's path: a fresh broker session (and fresh key material, and
    n fresh connections) every round."""
    from repro.net import run_safe_round_net

    Vw = rounds_vals[0].shape[1]
    t0 = time.perf_counter()
    out = []
    for r, vals in enumerate(rounds_vals):
        out.append(await run_safe_round_net(
            vals, addr, chunk_words=CHUNK, stream=stream,
            counter=r * Vw))
    return out, time.perf_counter() - t0


async def _persistent_rounds(addr, rounds_vals, *, interceptor=None):
    """One session, R rounds back-to-back, streaming combine on."""
    from repro.core import machines
    from repro.net import PersistentNetSession

    n = rounds_vals[0].shape[0]
    t0 = time.perf_counter()
    sess = PersistentNetSession(addr, n, chunk_words=CHUNK, stream=True,
                                interceptor=interceptor)
    await sess.open()
    try:
        d0 = machines.key_derivations()
        out = []
        derivs = []
        for vals in rounds_vals:
            out.append(await sess.run_round(vals))
            derivs.append(machines.key_derivations() - d0)
        wall = time.perf_counter() - t0
    finally:
        await sess.close()
    if any(d != derivs[0] for d in derivs[1:]):
        raise AssertionError(
            f"key material re-derived after Round 0: {derivs}")
    return out, wall


async def _pipelined_rounds(addr, rounds_vals, *, interceptor=None,
                            window=2):
    """ISSUE 9's path: one session, R rounds with §11 cross-round
    overlap — round r+1's chunk streams upload while round r's tail
    drains. Returns the per-round results, the wall time, and the
    broker's raw session stats (``chunk_frames_future`` is the direct
    proof that frames of round r+1 arrived while round r was current)."""
    from repro.net import PersistentNetSession

    n = rounds_vals[0].shape[0]
    t0 = time.perf_counter()
    sess = PersistentNetSession(addr, n, chunk_words=CHUNK, stream=True,
                                interceptor=interceptor)
    await sess.open()
    try:
        out = await sess.run_rounds_pipelined(rounds_vals, window=window)
        wall = time.perf_counter() - t0
        raw = await sess._admin.request("get_stats",
                                        {"session": sess.sid})
    finally:
        await sess.close()
    return out, wall, raw


async def _compare_rounds(rounds_vals):
    """The R-round A/B/C on one shared broker: warm one pass of each
    config first, then take each config's best of two timed passes —
    localhost wall times on a loaded box jitter at the 2x level and a
    single cold pass routinely inverts the ranking (the measured
    medians are stable; see EXPERIMENTS.md §Streaming)."""
    from repro.net import SafeBroker

    broker = SafeBroker(**BROKER_KW)
    addr = await broker.start()
    try:
        warm = rounds_vals[:1]
        await _rebuild_rounds(addr, warm, stream=False)
        await _persistent_rounds(addr, warm)
        await _pipelined_rounds(addr, warm)
        rebuild, wall_rebuild = await _rebuild_rounds(
            addr, rounds_vals, stream=False)
        persistent, wall_persist = await _persistent_rounds(
            addr, rounds_vals)
        pipelined, wall_pipe, raw = await _pipelined_rounds(
            addr, rounds_vals)
        _, wall_rebuild2 = await _rebuild_rounds(
            addr, rounds_vals, stream=False)
        _, wall_persist2 = await _persistent_rounds(addr, rounds_vals)
        _, wall_pipe2, raw2 = await _pipelined_rounds(addr, rounds_vals)
        if int(raw2["chunk_frames_future"]) > int(
                raw["chunk_frames_future"]):
            raw = raw2
        return (rebuild, min(wall_rebuild, wall_rebuild2),
                persistent, min(wall_persist, wall_persist2),
                pipelined, min(wall_pipe, wall_pipe2), raw)
    finally:
        await broker.stop()


def run() -> dict:
    from repro.core.protocol import run_safe_round

    out: dict = {"smoke": SMOKE, "V": V, "chunk_words": CHUNK,
                 "rounds": R, "host_cpus": HOST_CPUS}

    for n in NS:
        rng = np.random.RandomState(n)
        vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
        sim = run_safe_round(vals)

        # ---- one round: buffered vs streamed (best of two passes) ------
        buffered = asyncio.run(_one_round(vals, stream=False))
        streamed = asyncio.run(_one_round(vals, stream=True))
        b2 = asyncio.run(_one_round(vals, stream=False))
        s2 = asyncio.run(_one_round(vals, stream=True))
        buffered.wall_time = min(buffered.wall_time, b2.wall_time)
        streamed.wall_time = min(streamed.wall_time, s2.wall_time)
        for tag, res in (("buffered", buffered), ("streamed", streamed),
                         ("buffered2", b2), ("streamed2", s2)):
            if not np.array_equal(sim.average, res.average):
                raise AssertionError(f"{tag} n={n}: bits diverged from sim")
        if streamed.streamed_combines != n - 1:
            raise AssertionError(
                f"streaming engaged on {streamed.streamed_combines} of "
                f"{n - 1} hops")
        out[f"n{n}"] = {
            "buffered_1round_s": buffered.wall_time,
            "streamed_1round_s": streamed.wall_time,
            "stream_speedup_1round":
                buffered.wall_time / streamed.wall_time,
        }
        emit(f"streaming/buffered_1round_n{n}", buffered.wall_time * 1e6,
             f"msgs={buffered.stats['aggregation_total']}")
        emit(f"streaming/streamed_1round_n{n}", streamed.wall_time * 1e6,
             f"x{out[f'n{n}']['stream_speedup_1round']:.2f} vs buffered, "
             f"{streamed.streamed_combines} streamed hops")

        # ---- auto (stream=None) never loses to buffered at ANY n -------
        # the ISSUE 9 small-n fix: below MIN_STREAM_WORDS a frame-sized
        # payload now posts unchunked (no per-chunk consume handshakes),
        # so the auto path must hold the 1.6x noise bound even at the
        # n=4 smoke point that used to measure x0.81
        auto1 = asyncio.run(_one_round(vals, stream=None))
        auto2 = asyncio.run(_one_round(vals, stream=None))
        for res in (auto1, auto2):
            if not np.array_equal(sim.average, res.average):
                raise AssertionError(f"auto n={n}: bits diverged from sim")
        wall_auto_n = min(auto1.wall_time, auto2.wall_time)
        if wall_auto_n > buffered.wall_time * 1.6:
            raise AssertionError(
                f"auto path {wall_auto_n:.4f}s vs buffered "
                f"{buffered.wall_time:.4f}s at n={n}, V={V}: auto slower "
                f"than buffered beyond noise")
        out[f"n{n}"]["auto_1round_s"] = wall_auto_n
        out[f"n{n}"]["auto_over_buffered_1round"] = (
            wall_auto_n / buffered.wall_time)
        auto_path = "streamed" if auto1.streamed_combines else "fell back"
        emit(f"streaming/auto_1round_n{n}", wall_auto_n * 1e6,
             f"x{wall_auto_n / buffered.wall_time:.2f} vs buffered "
             f"(auto {auto_path})")

        # ---- R rounds: rebuild (PR 3) vs persistent vs pipelined -------
        rounds_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                       for _ in range(R)]
        (rebuild, wall_rebuild, persistent, wall_persist,
         pipelined, wall_pipe, raw_pipe) = asyncio.run(
            _compare_rounds(rounds_vals))
        pipe_msgs = []
        for r in range(R):
            sim_r = run_safe_round(rounds_vals[r], counter=r * V)
            for tag, res in (("rebuild", rebuild[r]),
                             ("persistent", persistent[r]),
                             ("pipelined", pipelined[r])):
                if not np.array_equal(sim_r.average, res.average):
                    raise AssertionError(
                        f"{tag} n={n} round {r}: bits diverged from sim")
            for tag, res in (("persistent", persistent[r]),
                             ("pipelined", pipelined[r])):
                if res.stats["aggregation_total"] != 4 * n:
                    raise AssertionError(
                        f"{tag} n={n} round {r}: closed form 4n broken")
            pipe_msgs.append(pipelined[r].stats["aggregation_total"])
        # direct §11 overlap proof: the broker accepted round r+1 chunk
        # frames while round r was still current
        if int(raw_pipe["chunk_frames_future"]) <= 0:
            raise AssertionError(
                f"pipelined n={n}: no future-round chunk frames — rounds "
                f"never overlapped on the wire")
        rps_rebuild = R / wall_rebuild
        rps_persist = R / wall_persist
        rps_pipe = R / wall_pipe
        out[f"n{n}"].update({
            "rebuild_rounds_per_s": rps_rebuild,
            "persistent_rounds_per_s": rps_persist,
            "persistent_speedup": rps_persist / rps_rebuild,
            "pipelined_rounds_per_s": rps_pipe,
            "pipelined_over_persistent": rps_pipe / rps_persist,
            "pipelined_chunk_frames_future":
                int(raw_pipe["chunk_frames_future"]),
            "pipelined_messages_per_round": pipe_msgs,
            "pipelined_bit_equal": True,
        })
        emit(f"streaming/rebuild_{R}rounds_n{n}",
             wall_rebuild / R * 1e6, f"{rps_rebuild:.2f} rounds/s (PR3 "
             f"per-round rebuild, buffered)")
        emit(f"streaming/persistent_{R}rounds_n{n}",
             wall_persist / R * 1e6,
             f"{rps_persist:.2f} rounds/s, "
             f"x{rps_persist / rps_rebuild:.2f} vs rebuild")
        emit(f"streaming/pipelined_{R}rounds_n{n}",
             wall_pipe / R * 1e6,
             f"{rps_pipe:.2f} rounds/s, "
             f"x{rps_pipe / rps_persist:.2f} vs persistent, "
             f"future_frames={int(raw_pipe['chunk_frames_future'])} "
             f"cpus={HOST_CPUS}")
        # strict win required at the largest n (the amortization target);
        # at small n the zero-copy relay shrank the rebuild cost enough
        # that the margin sits inside 1-core localhost noise, so those
        # rows only guard against a real regression (>10%)
        floor = 1.0 if n == max(NS) else 0.9
        if not SMOKE and rps_persist <= floor * rps_rebuild:
            raise AssertionError(
                f"persistent+streaming ({rps_persist:.2f} rounds/s) did "
                f"not beat {floor:.1f}x the rebuild path "
                f"({rps_rebuild:.2f}) at n={n}")
        # pipelining's bare-localhost win is cpu-gated: with 1 core the
        # overlapped round contends for the same CPU the draining round
        # needs, and wall clock can only tie — the WAN row below is
        # where a 1-core box demonstrates the §11 overlap honestly
        if (not SMOKE and n == max(NS) and HOST_CPUS >= 4
                and rps_pipe < 1.25 * rps_persist):
            raise AssertionError(
                f"pipelined ({rps_pipe:.2f} rounds/s) below x1.25 the "
                f"persistent path ({rps_persist:.2f}) at n={n} with "
                f"{HOST_CPUS} cpus")

    # ---- prefetch-depth ablation (picks DEFAULT_PREFETCH_DEPTH) --------
    n0 = NS[0]
    rng = np.random.RandomState(99)
    vals = rng.uniform(-1, 1, (n0, V)).astype(np.float32)
    out["prefetch"] = {}
    for d in DEPTHS:
        res = asyncio.run(_one_round(vals, stream=True, prefetch_depth=d))
        out["prefetch"][f"depth{d}_s"] = res.wall_time
        emit(f"streaming/prefetch_d{d}_n{n0}", res.wall_time * 1e6,
             f"depth={d}")

    # ---- auto path selection (wire.MIN_STREAM_WORDS, ISSUE 6) ----------
    # stream=None lets the client pick: BENCH_streaming measured the
    # streamed combine *losing* (x0.92) below ~16Ki words, where chunk
    # round-trips dominate and there is nothing to overlap — so small
    # payloads must auto-fall back to the buffered path, and the chosen
    # path must never be slower than buffered beyond wall-clock noise.
    from repro.net import wire

    n0 = NS[0]
    V_SMALL, CHUNK_SMALL = 1024, 256
    assert V_SMALL < wire.MIN_STREAM_WORDS  # the fallback side
    rng = np.random.RandomState(7)
    vals_small = rng.uniform(-1, 1, (n0, V_SMALL)).astype(np.float32)
    sim_small = run_safe_round(vals_small)

    def _best_of(k, **kw):
        res = [asyncio.run(_one_round(vals_small, chunk_words=CHUNK_SMALL,
                                      **kw)) for _ in range(k)]
        for r in res:
            if not np.array_equal(sim_small.average, r.average):
                raise AssertionError("auto-path bits diverged from sim")
        return res[0], min(r.wall_time for r in res)

    asyncio.run(_one_round(vals_small, chunk_words=CHUNK_SMALL,
                           stream=None))  # warm
    auto_small, wall_auto = _best_of(3, stream=None)
    _, wall_buf = _best_of(3, stream=False)
    if auto_small.streamed_combines != 0:
        raise AssertionError(
            f"V={V_SMALL} < MIN_STREAM_WORDS={wire.MIN_STREAM_WORDS} but "
            f"auto ran {auto_small.streamed_combines} streamed combines")
    # noise bound, not a perf claim: auto == buffered code path here, so
    # anything past 1.6x is a real regression, not localhost jitter
    if wall_auto > wall_buf * 1.6:
        raise AssertionError(
            f"auto path {wall_auto:.4f}s vs buffered {wall_buf:.4f}s at "
            f"V={V_SMALL}: chosen path slower than buffered beyond noise")
    auto_large = asyncio.run(_one_round(vals, stream=None))
    want_stream = V >= wire.MIN_STREAM_WORDS
    if bool(auto_large.streamed_combines) != want_stream:
        raise AssertionError(
            f"V={V}: auto ran {auto_large.streamed_combines} streamed "
            f"combines, expected {'n-1' if want_stream else '0'}")
    out["auto"] = {
        "min_stream_words": wire.MIN_STREAM_WORDS,
        "small_V": V_SMALL,
        "auto_small_s": wall_auto,
        "buffered_small_s": wall_buf,
        "auto_over_buffered": wall_auto / wall_buf,
        "large_V": V,
        "large_streamed": bool(auto_large.streamed_combines),
    }
    emit(f"streaming/auto_small_n{n0}", wall_auto * 1e6,
         f"x{wall_auto / wall_buf:.2f} vs buffered at V={V_SMALL} "
         f"(auto fell back, threshold {wire.MIN_STREAM_WORDS})")
    emit("streaming/auto_path", float(want_stream),
         f"V={V} -> {'streamed' if want_stream else 'buffered'}")

    # ---- adaptive chunk sizing (ISSUE 7 satellite) ---------------------
    # chunk_words="auto" derives the chunk size from the payload
    # (client.auto_chunk_words: ~8 chunks, MIN_STREAM_WORDS multiples)
    # instead of a fixed constant. The ROADMAP's x0.81–x1.02 losses at
    # smoke/small-n came from fixed chunks far below MIN_STREAM_WORDS;
    # the adaptive default must never be slower than the fixed one
    # beyond localhost noise (1.6x — same bound as the auto-path row).
    from repro.net import auto_chunk_words

    rng = np.random.RandomState(11)
    vals_ad = rng.uniform(-1, 1, (n0, V)).astype(np.float32)
    sim_ad = run_safe_round(vals_ad)
    aw = auto_chunk_words(V)
    if aw % wire.MIN_STREAM_WORDS:
        raise AssertionError(
            f"auto_chunk_words({V})={aw} is not a MIN_STREAM_WORDS "
            f"({wire.MIN_STREAM_WORDS}) multiple")

    def _best_of_adaptive(k, cw):
        res = [asyncio.run(_one_round(vals_ad, chunk_words=cw,
                                      stream=None)) for _ in range(k)]
        for r in res:
            if not np.array_equal(sim_ad.average, r.average):
                raise AssertionError(
                    "adaptive-chunk bits diverged from sim")
        return min(r.wall_time for r in res)

    asyncio.run(_one_round(vals_ad, chunk_words="auto",
                           stream=None))  # warm
    wall_adaptive = _best_of_adaptive(3, "auto")
    wall_fixed = _best_of_adaptive(3, CHUNK)
    if wall_adaptive > wall_fixed * 1.6:
        raise AssertionError(
            f"adaptive chunking {wall_adaptive:.4f}s vs fixed "
            f"chunk_words={CHUNK} {wall_fixed:.4f}s at V={V}: adaptive "
            f"default slower than fixed beyond noise")
    out["adaptive_chunk"] = {
        "auto_chunk_words": aw,
        "fixed_chunk_words": CHUNK,
        "adaptive_s": wall_adaptive,
        "fixed_s": wall_fixed,
        "adaptive_over_fixed": wall_adaptive / wall_fixed,
    }
    emit(f"streaming/adaptive_chunk_n{n0}", wall_adaptive * 1e6,
         f"x{wall_adaptive / wall_fixed:.2f} vs fixed {CHUNK} at V={V} "
         f"(auto picked {aw})")

    # ---- §11 pipelining under WAN latency (ISSUE 9) --------------------
    # On bare localhost a 1-core box cannot demonstrate cross-round
    # overlap in wall clock — both legs contend for the same CPU and the
    # honest rows above only gate where cores exist. Under a 10 ms-RTT
    # metro profile the round is latency-bound (asyncio sleeps model the
    # link, the shared CPU is real — the PR 5 honesty convention), so
    # uploading round r+1 while round r's tail drains buys real wall
    # clock even single-core; that is the §11 claim, and here it is
    # asserted at x1.25 (full runs; smoke records).
    from repro.net.faults import make_wan_interceptor

    rngw = np.random.RandomState(23)
    wan_vals = [rngw.uniform(-1, 1, (NS[0], V)).astype(np.float32)
                for _ in range(R)]

    async def _wan_pair():
        from repro.net import SafeBroker

        broker = SafeBroker(**BROKER_KW)
        addr = await broker.start()
        try:
            icpt = make_wan_interceptor("metro", seed=3)
            await _persistent_rounds(addr, wan_vals[:1], interceptor=icpt)
            await _pipelined_rounds(addr, wan_vals[:1], interceptor=icpt)
            pers, wall_p = await _persistent_rounds(
                addr, wan_vals, interceptor=icpt)
            pipe, wall_q, raw = await _pipelined_rounds(
                addr, wan_vals, interceptor=icpt)
            _, wall_p2 = await _persistent_rounds(
                addr, wan_vals, interceptor=icpt)
            _, wall_q2, _ = await _pipelined_rounds(
                addr, wan_vals, interceptor=icpt)
            return (pers, min(wall_p, wall_p2),
                    pipe, min(wall_q, wall_q2), raw)
        finally:
            await broker.stop()

    wan_pers, wan_wall_p, wan_pipe, wan_wall_q, wan_raw = asyncio.run(
        _wan_pair())
    for r in range(R):
        sim_r = run_safe_round(wan_vals[r], counter=r * V)
        for tag, res in (("persistent", wan_pers[r]),
                         ("pipelined", wan_pipe[r])):
            if not np.array_equal(sim_r.average, res.average):
                raise AssertionError(
                    f"wan {tag} round {r}: bits diverged from sim")
            if res.stats["aggregation_total"] != 4 * NS[0]:
                raise AssertionError(
                    f"wan {tag} round {r}: closed form 4n broken")
    wan_speedup = wan_wall_p / wan_wall_q
    if int(wan_raw["chunk_frames_future"]) <= 0:
        raise AssertionError("wan pipelined: rounds never overlapped")
    if not SMOKE and wan_speedup < 1.25:
        raise AssertionError(
            f"pipelined under 10 ms WAN only x{wan_speedup:.2f} vs "
            f"persistent (need >= x1.25: latency-bound rounds must "
            f"overlap)")
    out["pipelined_wan"] = {
        "profile": "metro",
        "rtt_ms": 10.0,
        "n": NS[0],
        "persistent_rounds_per_s": R / wan_wall_p,
        "pipelined_rounds_per_s": R / wan_wall_q,
        "pipelined_over_persistent": wan_speedup,
        "chunk_frames_future": int(wan_raw["chunk_frames_future"]),
        "host_cpus": HOST_CPUS,
        "bit_equal": True,
    }
    emit(f"streaming/pipelined_wan_n{NS[0]}", wan_wall_q / R * 1e6,
         f"x{wan_speedup:.2f} vs persistent at 10ms RTT, "
         f"future_frames={int(wan_raw['chunk_frames_future'])} "
         f"cpus={HOST_CPUS}")

    out["bit_equal"] = True  # every row above asserted it first
    emit("streaming/bit_equal", 1.0,
         "streamed == buffered == persistent == pipelined == sim, bitwise")
    save_json("streaming", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("streaming", run)
