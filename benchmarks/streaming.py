"""Streaming-combine + persistent-session ablation (ISSUE 4).

The §8 pipelining argument is SAFE's wall-clock case: crypto and
transfer of a split model overlap along the chain. This module prices
the two wire-plane halves of that claim against each other and against
PR 3's baseline:

  * **reassemble-then-combine vs. streaming combine**, one round: the
    buffered path (``stream=False`` — each learner downloads every
    chunk, reassembles, decrypts/adds/encrypts whole, re-uploads) vs.
    the chunk-granular combine (chunk k decrypted/added/re-encrypted
    and shipped downstream while chunk k+1 is in flight).
  * **per-round session rebuild vs. persistent multi-round sessions**,
    R rounds: PR 3's ``run_safe_round_net`` loop (create_session + n
    TCP connects + full key derivation *per round*) vs. ONE
    :class:`~repro.net.client.PersistentNetSession` (reset_round +
    RoundCursor counter bases between rounds; no key re-derivation
    after Round 0 — asserted here via ``machines.key_derivations()``).
  * **prefetch depth** {1, 2, 4}: the in-flight get_chunk budget whose
    winner is wire.DEFAULT_PREFETCH_DEPTH.

Bit-exactness is asserted in-harness at every n: the streamed, the
buffered, and every persistent round's published average must equal the
discrete-event sim's bitwise (rows only emit after the check passes;
the ``streaming/bit_equal`` row records it machine-readably for CI).

``SAFE_SMOKE=1`` shrinks n/V/R for CI. Standalone
(``python -m benchmarks.streaming``) writes ``BENCH_streaming.json``
(schema ``safe-bench/v1``). Measured numbers: EXPERIMENTS.md §Streaming.
"""
from __future__ import annotations

import asyncio
import os
import time

import numpy as np

from benchmarks.common import emit, save_json, standalone_bench

SMOKE = bool(os.environ.get("SAFE_SMOKE"))
NS = (4, 8) if SMOKE else (8, 36)
V = 4096 if SMOKE else 65536
CHUNK = 512 if SMOKE else 8192
R = 3 if SMOKE else 5
DEPTHS = (1, 2, 4)
BROKER_KW = dict(progress_timeout=2.0, monitor_interval=0.5,
                 aggregation_timeout=120.0)


async def _one_round(vals, *, stream, prefetch_depth=None):
    from repro.net import SafeBroker, run_safe_round_net

    broker = SafeBroker(**BROKER_KW)
    addr = await broker.start()
    try:
        return await run_safe_round_net(
            vals, addr, chunk_words=CHUNK, stream=stream,
            prefetch_depth=prefetch_depth)
    finally:
        await broker.stop()


async def _rebuild_rounds(addr, rounds_vals, *, stream):
    """PR 3's path: a fresh broker session (and fresh key material, and
    n fresh connections) every round."""
    from repro.net import run_safe_round_net

    Vw = rounds_vals[0].shape[1]
    t0 = time.perf_counter()
    out = []
    for r, vals in enumerate(rounds_vals):
        out.append(await run_safe_round_net(
            vals, addr, chunk_words=CHUNK, stream=stream,
            counter=r * Vw))
    return out, time.perf_counter() - t0


async def _persistent_rounds(addr, rounds_vals):
    """This PR's path: one session, R rounds, streaming combine on."""
    from repro.core import machines
    from repro.net import PersistentNetSession

    n = rounds_vals[0].shape[0]
    t0 = time.perf_counter()
    sess = PersistentNetSession(addr, n, chunk_words=CHUNK)
    await sess.open()
    try:
        d0 = machines.key_derivations()
        out = []
        derivs = []
        for vals in rounds_vals:
            out.append(await sess.run_round(vals))
            derivs.append(machines.key_derivations() - d0)
        wall = time.perf_counter() - t0
    finally:
        await sess.close()
    if any(d != derivs[0] for d in derivs[1:]):
        raise AssertionError(
            f"key material re-derived after Round 0: {derivs}")
    return out, wall


async def _compare_rounds(rounds_vals):
    """The R-round A/B on one shared broker: warm one pass of each
    config first, then take each config's best of two timed passes —
    localhost wall times on a loaded box jitter at the 2x level and a
    single cold pass routinely inverts the ranking (the measured
    medians are stable; see EXPERIMENTS.md §Streaming)."""
    from repro.net import SafeBroker

    broker = SafeBroker(**BROKER_KW)
    addr = await broker.start()
    try:
        warm = rounds_vals[:1]
        await _rebuild_rounds(addr, warm, stream=False)
        await _persistent_rounds(addr, warm)
        rebuild, wall_rebuild = await _rebuild_rounds(
            addr, rounds_vals, stream=False)
        persistent, wall_persist = await _persistent_rounds(
            addr, rounds_vals)
        _, wall_rebuild2 = await _rebuild_rounds(
            addr, rounds_vals, stream=False)
        _, wall_persist2 = await _persistent_rounds(addr, rounds_vals)
        return (rebuild, min(wall_rebuild, wall_rebuild2),
                persistent, min(wall_persist, wall_persist2))
    finally:
        await broker.stop()


def run() -> dict:
    from repro.core.protocol import run_safe_round

    out: dict = {"smoke": SMOKE, "V": V, "chunk_words": CHUNK, "rounds": R}

    for n in NS:
        rng = np.random.RandomState(n)
        vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
        sim = run_safe_round(vals)

        # ---- one round: buffered vs streamed (best of two passes) ------
        buffered = asyncio.run(_one_round(vals, stream=False))
        streamed = asyncio.run(_one_round(vals, stream=True))
        b2 = asyncio.run(_one_round(vals, stream=False))
        s2 = asyncio.run(_one_round(vals, stream=True))
        buffered.wall_time = min(buffered.wall_time, b2.wall_time)
        streamed.wall_time = min(streamed.wall_time, s2.wall_time)
        for tag, res in (("buffered", buffered), ("streamed", streamed),
                         ("buffered2", b2), ("streamed2", s2)):
            if not np.array_equal(sim.average, res.average):
                raise AssertionError(f"{tag} n={n}: bits diverged from sim")
        if streamed.streamed_combines != n - 1:
            raise AssertionError(
                f"streaming engaged on {streamed.streamed_combines} of "
                f"{n - 1} hops")
        out[f"n{n}"] = {
            "buffered_1round_s": buffered.wall_time,
            "streamed_1round_s": streamed.wall_time,
            "stream_speedup_1round":
                buffered.wall_time / streamed.wall_time,
        }
        emit(f"streaming/buffered_1round_n{n}", buffered.wall_time * 1e6,
             f"msgs={buffered.stats['aggregation_total']}")
        emit(f"streaming/streamed_1round_n{n}", streamed.wall_time * 1e6,
             f"x{out[f'n{n}']['stream_speedup_1round']:.2f} vs buffered, "
             f"{streamed.streamed_combines} streamed hops")

        # ---- R rounds: per-round rebuild (PR 3) vs persistent ----------
        rounds_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                       for _ in range(R)]
        rebuild, wall_rebuild, persistent, wall_persist = asyncio.run(
            _compare_rounds(rounds_vals))
        for r in range(R):
            sim_r = run_safe_round(rounds_vals[r], counter=r * V)
            for tag, res in (("rebuild", rebuild[r]),
                             ("persistent", persistent[r])):
                if not np.array_equal(sim_r.average, res.average):
                    raise AssertionError(
                        f"{tag} n={n} round {r}: bits diverged from sim")
            if persistent[r].stats["aggregation_total"] != 4 * n:
                raise AssertionError(
                    f"persistent n={n} round {r}: closed form 4n broken")
        rps_rebuild = R / wall_rebuild
        rps_persist = R / wall_persist
        out[f"n{n}"].update({
            "rebuild_rounds_per_s": rps_rebuild,
            "persistent_rounds_per_s": rps_persist,
            "persistent_speedup": rps_persist / rps_rebuild,
        })
        emit(f"streaming/rebuild_{R}rounds_n{n}",
             wall_rebuild / R * 1e6, f"{rps_rebuild:.2f} rounds/s (PR3 "
             f"per-round rebuild, buffered)")
        emit(f"streaming/persistent_{R}rounds_n{n}",
             wall_persist / R * 1e6,
             f"{rps_persist:.2f} rounds/s, "
             f"x{rps_persist / rps_rebuild:.2f} vs rebuild")
        if not SMOKE and rps_persist <= rps_rebuild:
            raise AssertionError(
                f"persistent+streaming ({rps_persist:.2f} rounds/s) did "
                f"not beat the rebuild path ({rps_rebuild:.2f}) at n={n}")

    # ---- prefetch-depth ablation (picks DEFAULT_PREFETCH_DEPTH) --------
    n0 = NS[0]
    rng = np.random.RandomState(99)
    vals = rng.uniform(-1, 1, (n0, V)).astype(np.float32)
    out["prefetch"] = {}
    for d in DEPTHS:
        res = asyncio.run(_one_round(vals, stream=True, prefetch_depth=d))
        out["prefetch"][f"depth{d}_s"] = res.wall_time
        emit(f"streaming/prefetch_d{d}_n{n0}", res.wall_time * 1e6,
             f"depth={d}")

    out["bit_equal"] = True  # every row above asserted it first
    emit("streaming/bit_equal", 1.0,
         "streamed == buffered == persistent == sim, bitwise")
    save_json("streaming", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("streaming", run)
