"""Streaming-combine + persistent-session ablation (ISSUE 4).

The §8 pipelining argument is SAFE's wall-clock case: crypto and
transfer of a split model overlap along the chain. This module prices
the two wire-plane halves of that claim against each other and against
PR 3's baseline:

  * **reassemble-then-combine vs. streaming combine**, one round: the
    buffered path (``stream=False`` — each learner downloads every
    chunk, reassembles, decrypts/adds/encrypts whole, re-uploads) vs.
    the chunk-granular combine (chunk k decrypted/added/re-encrypted
    and shipped downstream while chunk k+1 is in flight).
  * **per-round session rebuild vs. persistent multi-round sessions**,
    R rounds: PR 3's ``run_safe_round_net`` loop (create_session + n
    TCP connects + full key derivation *per round*) vs. ONE
    :class:`~repro.net.client.PersistentNetSession` (reset_round +
    RoundCursor counter bases between rounds; no key re-derivation
    after Round 0 — asserted here via ``machines.key_derivations()``).
  * **prefetch depth** {1, 2, 4}: the in-flight get_chunk budget whose
    winner is wire.DEFAULT_PREFETCH_DEPTH.
  * **auto path selection** (ISSUE 6): ``stream=None`` picks streamed
    vs. buffered by payload size (``wire.MIN_STREAM_WORDS``); asserted
    here that the fallback engages below the threshold and the chosen
    path is never slower than buffered beyond wall-clock noise.

Bit-exactness is asserted in-harness at every n: the streamed, the
buffered, and every persistent round's published average must equal the
discrete-event sim's bitwise (rows only emit after the check passes;
the ``streaming/bit_equal`` row records it machine-readably for CI).

``SAFE_SMOKE=1`` shrinks n/V/R for CI. Standalone
(``python -m benchmarks.streaming``) writes ``BENCH_streaming.json``
(schema ``safe-bench/v1``). Measured numbers: EXPERIMENTS.md §Streaming.
"""
from __future__ import annotations

import asyncio
import os
import time

import numpy as np

from benchmarks.common import emit, save_json, standalone_bench

SMOKE = bool(os.environ.get("SAFE_SMOKE"))
NS = (4, 8) if SMOKE else (8, 36)
V = 4096 if SMOKE else 65536
CHUNK = 512 if SMOKE else 8192
R = 3 if SMOKE else 5
DEPTHS = (1, 2, 4)
BROKER_KW = dict(progress_timeout=2.0, monitor_interval=0.5,
                 aggregation_timeout=120.0)


async def _one_round(vals, *, stream, prefetch_depth=None,
                     chunk_words=None):
    from repro.net import SafeBroker, run_safe_round_net

    broker = SafeBroker(**BROKER_KW)
    addr = await broker.start()
    try:
        return await run_safe_round_net(
            vals, addr, chunk_words=chunk_words or CHUNK, stream=stream,
            prefetch_depth=prefetch_depth)
    finally:
        await broker.stop()


async def _rebuild_rounds(addr, rounds_vals, *, stream):
    """PR 3's path: a fresh broker session (and fresh key material, and
    n fresh connections) every round."""
    from repro.net import run_safe_round_net

    Vw = rounds_vals[0].shape[1]
    t0 = time.perf_counter()
    out = []
    for r, vals in enumerate(rounds_vals):
        out.append(await run_safe_round_net(
            vals, addr, chunk_words=CHUNK, stream=stream,
            counter=r * Vw))
    return out, time.perf_counter() - t0


async def _persistent_rounds(addr, rounds_vals):
    """This PR's path: one session, R rounds, streaming combine on."""
    from repro.core import machines
    from repro.net import PersistentNetSession

    n = rounds_vals[0].shape[0]
    t0 = time.perf_counter()
    sess = PersistentNetSession(addr, n, chunk_words=CHUNK, stream=True)
    await sess.open()
    try:
        d0 = machines.key_derivations()
        out = []
        derivs = []
        for vals in rounds_vals:
            out.append(await sess.run_round(vals))
            derivs.append(machines.key_derivations() - d0)
        wall = time.perf_counter() - t0
    finally:
        await sess.close()
    if any(d != derivs[0] for d in derivs[1:]):
        raise AssertionError(
            f"key material re-derived after Round 0: {derivs}")
    return out, wall


async def _compare_rounds(rounds_vals):
    """The R-round A/B on one shared broker: warm one pass of each
    config first, then take each config's best of two timed passes —
    localhost wall times on a loaded box jitter at the 2x level and a
    single cold pass routinely inverts the ranking (the measured
    medians are stable; see EXPERIMENTS.md §Streaming)."""
    from repro.net import SafeBroker

    broker = SafeBroker(**BROKER_KW)
    addr = await broker.start()
    try:
        warm = rounds_vals[:1]
        await _rebuild_rounds(addr, warm, stream=False)
        await _persistent_rounds(addr, warm)
        rebuild, wall_rebuild = await _rebuild_rounds(
            addr, rounds_vals, stream=False)
        persistent, wall_persist = await _persistent_rounds(
            addr, rounds_vals)
        _, wall_rebuild2 = await _rebuild_rounds(
            addr, rounds_vals, stream=False)
        _, wall_persist2 = await _persistent_rounds(addr, rounds_vals)
        return (rebuild, min(wall_rebuild, wall_rebuild2),
                persistent, min(wall_persist, wall_persist2))
    finally:
        await broker.stop()


def run() -> dict:
    from repro.core.protocol import run_safe_round

    out: dict = {"smoke": SMOKE, "V": V, "chunk_words": CHUNK, "rounds": R}

    for n in NS:
        rng = np.random.RandomState(n)
        vals = rng.uniform(-1, 1, (n, V)).astype(np.float32)
        sim = run_safe_round(vals)

        # ---- one round: buffered vs streamed (best of two passes) ------
        buffered = asyncio.run(_one_round(vals, stream=False))
        streamed = asyncio.run(_one_round(vals, stream=True))
        b2 = asyncio.run(_one_round(vals, stream=False))
        s2 = asyncio.run(_one_round(vals, stream=True))
        buffered.wall_time = min(buffered.wall_time, b2.wall_time)
        streamed.wall_time = min(streamed.wall_time, s2.wall_time)
        for tag, res in (("buffered", buffered), ("streamed", streamed),
                         ("buffered2", b2), ("streamed2", s2)):
            if not np.array_equal(sim.average, res.average):
                raise AssertionError(f"{tag} n={n}: bits diverged from sim")
        if streamed.streamed_combines != n - 1:
            raise AssertionError(
                f"streaming engaged on {streamed.streamed_combines} of "
                f"{n - 1} hops")
        out[f"n{n}"] = {
            "buffered_1round_s": buffered.wall_time,
            "streamed_1round_s": streamed.wall_time,
            "stream_speedup_1round":
                buffered.wall_time / streamed.wall_time,
        }
        emit(f"streaming/buffered_1round_n{n}", buffered.wall_time * 1e6,
             f"msgs={buffered.stats['aggregation_total']}")
        emit(f"streaming/streamed_1round_n{n}", streamed.wall_time * 1e6,
             f"x{out[f'n{n}']['stream_speedup_1round']:.2f} vs buffered, "
             f"{streamed.streamed_combines} streamed hops")

        # ---- R rounds: per-round rebuild (PR 3) vs persistent ----------
        rounds_vals = [rng.uniform(-1, 1, (n, V)).astype(np.float32)
                       for _ in range(R)]
        rebuild, wall_rebuild, persistent, wall_persist = asyncio.run(
            _compare_rounds(rounds_vals))
        for r in range(R):
            sim_r = run_safe_round(rounds_vals[r], counter=r * V)
            for tag, res in (("rebuild", rebuild[r]),
                             ("persistent", persistent[r])):
                if not np.array_equal(sim_r.average, res.average):
                    raise AssertionError(
                        f"{tag} n={n} round {r}: bits diverged from sim")
            if persistent[r].stats["aggregation_total"] != 4 * n:
                raise AssertionError(
                    f"persistent n={n} round {r}: closed form 4n broken")
        rps_rebuild = R / wall_rebuild
        rps_persist = R / wall_persist
        out[f"n{n}"].update({
            "rebuild_rounds_per_s": rps_rebuild,
            "persistent_rounds_per_s": rps_persist,
            "persistent_speedup": rps_persist / rps_rebuild,
        })
        emit(f"streaming/rebuild_{R}rounds_n{n}",
             wall_rebuild / R * 1e6, f"{rps_rebuild:.2f} rounds/s (PR3 "
             f"per-round rebuild, buffered)")
        emit(f"streaming/persistent_{R}rounds_n{n}",
             wall_persist / R * 1e6,
             f"{rps_persist:.2f} rounds/s, "
             f"x{rps_persist / rps_rebuild:.2f} vs rebuild")
        # strict win required at the largest n (the amortization target);
        # at small n the zero-copy relay shrank the rebuild cost enough
        # that the margin sits inside 1-core localhost noise, so those
        # rows only guard against a real regression (>10%)
        floor = 1.0 if n == max(NS) else 0.9
        if not SMOKE and rps_persist <= floor * rps_rebuild:
            raise AssertionError(
                f"persistent+streaming ({rps_persist:.2f} rounds/s) did "
                f"not beat {floor:.1f}x the rebuild path "
                f"({rps_rebuild:.2f}) at n={n}")

    # ---- prefetch-depth ablation (picks DEFAULT_PREFETCH_DEPTH) --------
    n0 = NS[0]
    rng = np.random.RandomState(99)
    vals = rng.uniform(-1, 1, (n0, V)).astype(np.float32)
    out["prefetch"] = {}
    for d in DEPTHS:
        res = asyncio.run(_one_round(vals, stream=True, prefetch_depth=d))
        out["prefetch"][f"depth{d}_s"] = res.wall_time
        emit(f"streaming/prefetch_d{d}_n{n0}", res.wall_time * 1e6,
             f"depth={d}")

    # ---- auto path selection (wire.MIN_STREAM_WORDS, ISSUE 6) ----------
    # stream=None lets the client pick: BENCH_streaming measured the
    # streamed combine *losing* (x0.92) below ~16Ki words, where chunk
    # round-trips dominate and there is nothing to overlap — so small
    # payloads must auto-fall back to the buffered path, and the chosen
    # path must never be slower than buffered beyond wall-clock noise.
    from repro.net import wire

    n0 = NS[0]
    V_SMALL, CHUNK_SMALL = 1024, 256
    assert V_SMALL < wire.MIN_STREAM_WORDS  # the fallback side
    rng = np.random.RandomState(7)
    vals_small = rng.uniform(-1, 1, (n0, V_SMALL)).astype(np.float32)
    sim_small = run_safe_round(vals_small)

    def _best_of(k, **kw):
        res = [asyncio.run(_one_round(vals_small, chunk_words=CHUNK_SMALL,
                                      **kw)) for _ in range(k)]
        for r in res:
            if not np.array_equal(sim_small.average, r.average):
                raise AssertionError("auto-path bits diverged from sim")
        return res[0], min(r.wall_time for r in res)

    asyncio.run(_one_round(vals_small, chunk_words=CHUNK_SMALL,
                           stream=None))  # warm
    auto_small, wall_auto = _best_of(3, stream=None)
    _, wall_buf = _best_of(3, stream=False)
    if auto_small.streamed_combines != 0:
        raise AssertionError(
            f"V={V_SMALL} < MIN_STREAM_WORDS={wire.MIN_STREAM_WORDS} but "
            f"auto ran {auto_small.streamed_combines} streamed combines")
    # noise bound, not a perf claim: auto == buffered code path here, so
    # anything past 1.6x is a real regression, not localhost jitter
    if wall_auto > wall_buf * 1.6:
        raise AssertionError(
            f"auto path {wall_auto:.4f}s vs buffered {wall_buf:.4f}s at "
            f"V={V_SMALL}: chosen path slower than buffered beyond noise")
    auto_large = asyncio.run(_one_round(vals, stream=None))
    want_stream = V >= wire.MIN_STREAM_WORDS
    if bool(auto_large.streamed_combines) != want_stream:
        raise AssertionError(
            f"V={V}: auto ran {auto_large.streamed_combines} streamed "
            f"combines, expected {'n-1' if want_stream else '0'}")
    out["auto"] = {
        "min_stream_words": wire.MIN_STREAM_WORDS,
        "small_V": V_SMALL,
        "auto_small_s": wall_auto,
        "buffered_small_s": wall_buf,
        "auto_over_buffered": wall_auto / wall_buf,
        "large_V": V,
        "large_streamed": bool(auto_large.streamed_combines),
    }
    emit(f"streaming/auto_small_n{n0}", wall_auto * 1e6,
         f"x{wall_auto / wall_buf:.2f} vs buffered at V={V_SMALL} "
         f"(auto fell back, threshold {wire.MIN_STREAM_WORDS})")
    emit("streaming/auto_path", float(want_stream),
         f"V={V} -> {'streamed' if want_stream else 'buffered'}")

    # ---- adaptive chunk sizing (ISSUE 7 satellite) ---------------------
    # chunk_words="auto" derives the chunk size from the payload
    # (client.auto_chunk_words: ~8 chunks, MIN_STREAM_WORDS multiples)
    # instead of a fixed constant. The ROADMAP's x0.81–x1.02 losses at
    # smoke/small-n came from fixed chunks far below MIN_STREAM_WORDS;
    # the adaptive default must never be slower than the fixed one
    # beyond localhost noise (1.6x — same bound as the auto-path row).
    from repro.net import auto_chunk_words

    rng = np.random.RandomState(11)
    vals_ad = rng.uniform(-1, 1, (n0, V)).astype(np.float32)
    sim_ad = run_safe_round(vals_ad)
    aw = auto_chunk_words(V)
    if aw % wire.MIN_STREAM_WORDS:
        raise AssertionError(
            f"auto_chunk_words({V})={aw} is not a MIN_STREAM_WORDS "
            f"({wire.MIN_STREAM_WORDS}) multiple")

    def _best_of_adaptive(k, cw):
        res = [asyncio.run(_one_round(vals_ad, chunk_words=cw,
                                      stream=None)) for _ in range(k)]
        for r in res:
            if not np.array_equal(sim_ad.average, r.average):
                raise AssertionError(
                    "adaptive-chunk bits diverged from sim")
        return min(r.wall_time for r in res)

    asyncio.run(_one_round(vals_ad, chunk_words="auto",
                           stream=None))  # warm
    wall_adaptive = _best_of_adaptive(3, "auto")
    wall_fixed = _best_of_adaptive(3, CHUNK)
    if wall_adaptive > wall_fixed * 1.6:
        raise AssertionError(
            f"adaptive chunking {wall_adaptive:.4f}s vs fixed "
            f"chunk_words={CHUNK} {wall_fixed:.4f}s at V={V}: adaptive "
            f"default slower than fixed beyond noise")
    out["adaptive_chunk"] = {
        "auto_chunk_words": aw,
        "fixed_chunk_words": CHUNK,
        "adaptive_s": wall_adaptive,
        "fixed_s": wall_fixed,
        "adaptive_over_fixed": wall_adaptive / wall_fixed,
    }
    emit(f"streaming/adaptive_chunk_n{n0}", wall_adaptive * 1e6,
         f"x{wall_adaptive / wall_fixed:.2f} vs fixed {CHUNK} at V={V} "
         f"(auto picked {aw})")

    out["bit_equal"] = True  # every row above asserted it first
    emit("streaming/bit_equal", 1.0,
         "streamed == buffered == persistent == sim, bitwise")
    save_json("streaming", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("streaming", run)
