"""Figures 10-12: aggregation time vs feature-vector size.

Fixed node counts (3, 15 with BON; 100 SAFE-only), features 1..10000.
Shows the paper's crossover: SAFE beats INSEC at large feature counts
because the binary masked payload beats the raw-JSON baseline (modeled
via the per-byte cost), and BON's pad expansion scales with n·V.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.bon_protocol import run_bon_round
from repro.core.protocol import run_safe_round

FEATURES = (1, 10, 100, 1000, 10000)


def run(nodes: int, include_bon: bool) -> dict:
    out = {"nodes": nodes, "features": list(FEATURES), "series": {}}
    for mode in ("insec", "saf", "safe"):
        ts = []
        for V in FEATURES:
            vals = np.random.RandomState(V).uniform(-1, 1, (nodes, V)) \
                .astype(np.float32)
            ts.append(run_safe_round(vals, mode=mode).virtual_time)
        out["series"][mode] = ts
        emit(f"fig10-12/{mode}/n{nodes}/f{FEATURES[-1]}", ts[-1] * 1e6,
             f"virtual_s={ts[-1]:.4f}")
    if include_bon:
        ts = []
        for V in FEATURES:
            vals = np.random.RandomState(V).uniform(-1, 1, (nodes, V)) \
                .astype(np.float32)
            ts.append(run_bon_round(vals).virtual_time)
        out["series"]["bon"] = ts
        emit(f"fig10-12/bon/n{nodes}/f{FEATURES[-1]}", ts[-1] * 1e6,
             f"virtual_s={ts[-1]:.4f}")
    # crossover feature count between SAFE and INSEC (paper: ~2000 @15)
    cross = None
    for V, ti, ts_ in zip(FEATURES, out["series"]["insec"],
                          out["series"]["safe"]):
        if ts_ < ti:
            cross = V
            break
    out["safe_beats_insec_at"] = cross
    emit(f"fig10-12/crossover/n{nodes}", 0.0, f"features={cross}")
    save_json(f"feature_scalability_n{nodes}", out)
    return out


def main():
    run(3, include_bon=True)
    run(15, include_bon=True)
    run(100, include_bon=False)


if __name__ == "__main__":
    main()
