"""§Roofline: derive the three roofline terms from the dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective term = collective_bytes / (chips × 50e9 B/s ICI link)

cost_analysis() on the partitioned module is already per-device, so the
per-chip division is implicit; collective bytes come from the HLO parse
(dryrun.parse_collectives) which is also per-device.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens processed
per step; the ratio MODEL/HLO exposes remat and redundant compute.

Writes results/roofline.md (the EXPERIMENTS.md §Roofline table) and
prints CSV rows.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_json

PEAK_FLOPS = 197e12  # TPU v5e bf16 / chip
HBM_BW = 819e9       # B/s / chip
ICI_BW = 50e9        # B/s / link

TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,      # one token per sequence
    "long_500k": 1,
}
TRAIN_MULT = {"train_4k": 3.0}  # fwd+bwd ≈ 3x forward flops


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "pod512" else 256
    flops = rec["cost"]["flops"]                    # per device
    bytes_ = rec["cost"]["bytes_accessed"]          # per device
    coll = rec["collectives"]["total_bytes"]        # per device
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_params = rec["active_params"]
    tokens = TOKENS[rec["shape"]]
    mult = TRAIN_MULT.get(rec["shape"], 1.0)
    model_flops_total = 2.0 * n_params * tokens * mult  # 2ND fwd (6ND train)
    model_flops_dev = model_flops_total / chips
    # CAVEAT: XLA cost_analysis counts while-loop (unit-scan) bodies ONCE,
    # so hlo flops/bytes undercount by ~n_units for deep models. The
    # compute term therefore uses max(HLO, analytic 6·N·D); the ratio
    # column flags where the undercount (or remat/redundancy excess) is.
    t_compute = max(flops, model_flops_dev) / PEAK_FLOPS
    terms["compute"] = t_compute
    dominant = max(terms, key=terms.get)
    useful = model_flops_dev / flops if flops else 0.0

    moves = {
        "compute": "increase arithmetic intensity: larger per-device batch "
                   "or less remat recompute",
        "memory": "fuse masking ops / cast gathers to bf16 / cut activation "
                  "re-reads (remat policy)",
        "collective": "pipeline the chain (rotated-initiator segments), "
                      "shard the chain vector over 'model', or subgroup",
    }[dominant]
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh")},
        "aggregator": rec.get("aggregator"),
        "description": rec.get("description", ""),
        "mem_gib": rec["memory"]["total_per_device_bytes"] / 2**30,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": model_flops_dev,
        "hlo_flops_per_dev": flops,
        "useful_flops_ratio": useful,
        "move": moves,
    }


def run(pattern: str = "*") -> list[dict]:
    rows = []
    skips = []
    for path in sorted(glob.glob(f"results/dryrun/{pattern}.json")):
        rec = json.load(open(path))
        row = analyze_record(rec)
        if row is None:
            skips.append((rec.get("arch"), rec.get("shape"),
                          rec.get("status"), rec.get("reason", rec.get("error", ""))[:60]))
            continue
        rows.append(row)
        emit(f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}",
             max(row["t_compute_s"], row["t_memory_s"],
                 row["t_collective_s"]) * 1e6,
             f"dom={row['dominant']} comp={row['t_compute_s']:.3f}s "
             f"mem={row['t_memory_s']:.3f}s coll={row['t_collective_s']:.3f}s "
             f"useful={row['useful_flops_ratio']:.2f}")

    lines = [
        "| arch | shape | mesh | mem GiB/dev | compute s | memory s | "
        "collective s | dominant | useful FLOPs | what moves it |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['mem_gib']:.1f} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['move']} |")
    if skips:
        lines.append("")
        lines.append("Skipped/failed:")
        for s in skips:
            lines.append(f"- {s[0]} {s[1]}: {s[2]} {s[3]}")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    save_json("roofline", rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
