"""Device data-plane benchmark: SAFE chain vs psum vs BON on a host mesh.

Runs in a subprocess with 8 host devices (the bench process itself stays
single-device). Wall time on CPU is not TPU-predictive — the *derived*
columns (bytes over the learner axis per aggregation, PRF work) are the
roofline-relevant outputs; wall time just sanity-checks the orderings.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit, save_json

_CODE = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_aggregator

mesh = jax.make_mesh((8,), ("data",))
n, V = 8, 1 << 20
vals = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (n, V))
                   .astype(np.float32))
out = {}
for name, agg in [
    ("insec", make_aggregator("insec", n)),
    ("safe_sequential", make_aggregator("safe", n)),
    ("safe_pipelined", make_aggregator("safe", n, pipelined=True)),
    ("safe_subgroups2", make_aggregator("safe", n, subgroups=2)),
    ("saf", make_aggregator("saf", n)),
    ("bon", make_aggregator("bon", n)),
]:
    r = agg.aggregate_sharded(mesh, vals)  # compile+run once
    jax.block_until_ready(r)
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(agg.aggregate_sharded(mesh, vals))
        ts.append(time.perf_counter() - t0)
    # derived: bytes crossing the learner axis per aggregation (per link)
    hops = {"insec": 2, "saf": n, "safe_sequential": n,
            "safe_pipelined": 2, "safe_subgroups2": n // 2 + 1,
            "bon": 2}[name]
    out[name] = {"wall_s": sorted(ts)[1],
                 "axis_bytes_per_learner": hops * V * 4,
                 "prf_streams_per_learner":
                     {"insec": 0, "saf": 1, "safe_sequential": 3,
                      "safe_pipelined": 3, "safe_subgroups2": 3,
                      "bon": n + 1}[name]}
print("JSON" + json.dumps(out))
"""


def run() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(_CODE)],
                          capture_output=True, text=True, timeout=1200,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    payload = json.loads(proc.stdout.split("JSON", 1)[1])
    for name, row in payload.items():
        emit(f"device_agg/{name}", row["wall_s"] * 1e6,
             f"axis_MB={row['axis_bytes_per_learner']/2**20:.0f} "
             f"prf_streams={row['prf_streams_per_learner']}")
    save_json("device_aggregation", payload)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()
