"""Shared benchmark plumbing: timing, CSV rows, result persistence."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")

_rows: list[tuple] = []
_payloads: dict = {}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    _rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def rows():
    return list(_rows)


def save_json(name: str, payload) -> str:
    """Record a module's detailed result payload.

    Unprefixed names no longer write their own ``<name>.json`` — that
    produced stale twins drifting beside the schema'd files (ISSUE 8).
    Instead the payload is stashed and folded into the module's
    ``BENCH_<module>.json`` under the ``payloads`` key by the next
    :func:`save_bench_json` (the ``run.py --bench-json`` harness or a
    ``standalone_bench`` run). Only ``BENCH_``-prefixed names touch
    disk; tests/test_benchmarks.py rejects any other write.
    """
    if not name.startswith("BENCH_"):
        _payloads[name] = payload
        return ""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


#: stable machine-readable schema version for BENCH_<name>.json files —
#: bump only on breaking layout changes so perf-trajectory tooling can
#: parse every historical run.
BENCH_SCHEMA = "safe-bench/v1"


def save_bench_json(name: str, bench_rows: list, status: str,
                    wall_s: float) -> str:
    """Write results/benchmarks/BENCH_<name>.json with the stable schema:

    {"schema": "safe-bench/v1", "name": ..., "status": "ok"|"failed",
     "wall_s": ..., "rows": [{"name", "us_per_call", "derived"}, ...],
     "payloads": {<save_json name>: <payload>, ...}}

    ``payloads`` drains every :func:`save_json` stash accumulated since
    the previous drain — the module's detailed dicts travel inside its
    schema'd file instead of as unprefixed twins. Additive to
    ``safe-bench/v1``: readers of ``rows`` are unaffected.
    """
    payload = {
        "schema": BENCH_SCHEMA,
        "name": name,
        "status": status,
        "wall_s": wall_s,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for (n, us, d) in bench_rows],
    }
    if _payloads:
        payload["payloads"] = dict(_payloads)
        _payloads.clear()
    return save_json(f"BENCH_{name}", payload)


def standalone_bench(key: str, fn: Callable) -> None:
    """Run one benchmark module standalone (``python -m benchmarks.X``)
    with the same stable ``BENCH_<key>.json`` emission the ``run.py``
    harness performs — so a module run on its own still feeds the
    machine-readable perf trajectory instead of only its legacy JSON."""
    before = len(rows())
    t0 = time.time()
    status = "ok"
    try:
        fn()
    except Exception as e:  # noqa: BLE001
        status = "failed"
        print(f"# FAILED {key}: {e!r}", flush=True)
        raise
    finally:
        save_bench_json(key, rows()[before:], status, time.time() - t0)


def run_device_subprocess(code: str, devices: int = 8,
                          timeout: int = 1800) -> dict:
    """Run benchmark ``code`` in a child python with N host devices and
    parse its ``print("JSON" + json.dumps(payload))`` sentinel line.

    jax locks the host device count at first init, so anything needing a
    mesh runs in a subprocess with XLA_FLAGS set before jax imports —
    the shared boilerplate of multi_session / net_load style benches.
    """
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return json.loads(proc.stdout.split("JSON", 1)[1])


def wall(fn: Callable, repeats: int = 3) -> float:
    """Median wall time of fn() in seconds."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
