"""Figures 13-14: failover overhead, SAFE vs BON.

Protocol: complete the key exchange, kill nodes 4-6, run the aggregation,
and compare against a no-failure run with the same number of *completing*
nodes (the paper's footnote-4 normalization). Failover overhead = total
time − the failure-detection timeout (progress timeouts for SAFE, the
global dropout wait for BON).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.bon_protocol import run_bon_round
from repro.core.protocol import run_safe_round

FAILED = (4, 5, 6)
TIMEOUT = 1.0  # progress timeout per failed node (SAFE); summed for BON


def run() -> dict:
    node_counts = (9, 12, 18, 24, 30, 36)
    out = {"nodes": list(node_counts), "failed": list(FAILED), "series": {}}
    f = len(FAILED)
    safe, safe_fo, bon, bon_fo = [], [], [], []
    for n in node_counts:
        rng = np.random.RandomState(n)
        vals_ok = rng.uniform(-1, 1, (n - f, 1)).astype(np.float32)
        vals_f = rng.uniform(-1, 1, (n, 1)).astype(np.float32)
        safe.append(run_safe_round(vals_ok).virtual_time)
        r = run_safe_round(vals_f, failed_nodes=FAILED,
                           progress_timeout=TIMEOUT)
        safe_fo.append(r.virtual_time - f * TIMEOUT)  # subtract timeouts
        bon.append(run_bon_round(vals_ok).virtual_time)
        rb = run_bon_round(vals_f, failed_nodes=FAILED,
                           global_timeout=f * TIMEOUT)
        bon_fo.append(rb.virtual_time - f * TIMEOUT)
    out["series"] = {"safe": safe, "safe_failover": safe_fo,
                     "bon": bon, "bon_failover": bon_fo}
    for i, n in enumerate(node_counts):
        emit(f"fig13/n{n}", safe_fo[i] * 1e6,
             f"safe={safe[i]:.3f} safe_fo={safe_fo[i]:.3f} "
             f"bon={bon[i]:.3f} bon_fo={bon_fo[i]:.3f}")
    # headline ratios (paper @36: 56x no-failover, 70x with)
    i36 = node_counts.index(36)
    out["ratio_36"] = {"bon_over_safe": bon[i36] / safe[i36],
                       "bon_fo_over_safe_fo": bon_fo[i36] / safe_fo[i36]}
    emit("fig13/ratio36", 0.0,
         f"bon/safe={out['ratio_36']['bon_over_safe']:.1f}x "
         f"bon_fo/safe_fo={out['ratio_36']['bon_fo_over_safe_fo']:.1f}x")
    save_json("failover", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
