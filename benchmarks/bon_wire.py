"""BON-over-the-wire bake-off + WAN-calibrated cost model (ISSUE 8).

The §6.1 comparison so far rested on one real leg and one simulated
leg: SAFE rounds ran over real TCP (``benchmarks/paper_scale``) while
the Bonawitz-style baseline existed only as a discrete-event simulation
(``core/bon_protocol``). This module closes the gap — BON runs through
the *same* asyncio broker, wire codec and learner runtime as SAFE
(opcodes 20–27, docs/PROTOCOL.md §14), so both protocols are measured
on identical transport under identical fault schedules:

  * ``safe_nN`` / ``bon_nN`` (clean and ``_fK``) — head-to-head rounds
    at n ∈ {8, 36, 128}. Closed-form message counts (SAFE 4n /
    4(n−f)+2f, BON 2n + 2n(n−1) + ℓ(n+2)) and sim↔wire bit-identity
    are asserted *inside* :func:`repro.net.loadgen.run_paper_scale` /
    :func:`~repro.net.loadgen.run_bon_scale` — a row that prints has
    already validated itself. BON at n=128 is ~33k RPCs, so it runs
    only on the clean localhost transport (and never under SMOKE).
  * ``wan/<profile>`` — both protocols at n=36 under the calibrated
    WAN profiles of ``repro.net.faults.WAN_PROFILES`` (10–200 ms RTT,
    loss, heavy-tail lognormal jitter). Rows carry the declared link
    metadata (rtt_ms/loss/kind) and the host cpu count next to the
    measured wall time — localhost asyncio sleeps model the link, the
    CPU is real and shared, so the annotation states what was actually
    measured (the PR 5 honesty convention).
  * ``fit/*`` — per-op micro-latencies measured on this host (RPC echo
    at two payload sizes → t_msg/t_byte; Shamir share/reconstruct →
    t_share; PRF keystream → t_prf_word; vector add → t_add_elem), fed
    to :meth:`repro.core.costs.CostModel.fit`. The fitted model re-runs
    both §6.1 simulations, and the payload lands measured-vs-modeled
    ratios side by side with the fit residuals — the cost model becomes
    a calibrated instrument with an error bar instead of a constant
    table.

``SAFE_SMOKE=1`` shrinks to n=8 and one WAN profile for CI. Measured
numbers and regeneration commands live in EXPERIMENTS.md §BON-wire.
"""
from __future__ import annotations

import asyncio
import os
import time

import numpy as np

from benchmarks.common import emit, save_json, standalone_bench

SMOKE = bool(os.environ.get("SAFE_SMOKE"))
FAILED = (4, 5, 6)  # the paper's failover experiment takes out nodes 4-6
WAN_N = 8 if SMOKE else 36
WAN_PROFILES_RUN = ("continental",) if SMOKE else (
    "continental", "intercontinental_tail")


def _emit_row(key: str, row: dict) -> None:
    emit(f"bon_wire/{key}", row["wall_s"] * 1e6,
         f"msgs={row['messages']} (closed form {row['expected_messages']}) "
         f"bytes={row['bytes_sent']} bit_identical={row['bit_identical']}")


async def _measure_rpc(samples: list) -> None:
    """RPC echo at two payload sizes → (t_msg, t_byte) fit samples.

    A throwaway BON session gives us both shapes on the real wire:
    ``get_stats`` is a ~100-byte round trip (pure t_msg), and
    ``bon_post_masked`` carries a V-word uint32 vector (t_byte leg) —
    each node id accepts exactly one masked post, so a session with K
    nodes yields K independent big-payload RPCs.
    """
    from repro.net.broker import SafeBroker
    from repro.net.client import WireClient

    K, V_BIG = 12, 65536
    broker = SafeBroker()
    host, port = await broker.start()
    cli = await WireClient(host, port, node=1).connect()
    try:
        sid = (await cli.request("create_session", {
            "groups": {0: list(range(1, K + 1))}, "protocol": "bon",
            "aggregation_timeout": 60.0}))["session"]
        small_b = 128   # approx frame bytes both ways (header-dominated)
        for _ in range(K):
            t0 = time.perf_counter()
            await cli.request("get_stats", {"session": sid})
            samples.append(({"t_msg": 1.0, "t_byte": small_b},
                            time.perf_counter() - t0))
        payload = np.zeros(V_BIG, np.uint32)
        for node in range(1, K + 1):
            t0 = time.perf_counter()
            await cli.request("bon_post_masked", {
                "session": sid, "node": node, "payload": payload})
            samples.append(({"t_msg": 1.0, "t_byte": 4.0 * V_BIG},
                            time.perf_counter() - t0))
        await cli.request("delete_session", {"session": sid})
    finally:
        await cli.close()
        await broker.stop()


def _measure_compute(samples: list) -> None:
    """Local micro-ops → t_share / t_prf_word / t_add_elem samples."""
    import random

    from repro.core.shamir import reconstruct, share
    from repro.crypto.np_impl import keystream_pair_lanes_np

    rng = random.Random(11)
    reps = 3 if SMOKE else 8
    for _ in range(reps):
        secret = rng.getrandbits(64)
        t0 = time.perf_counter()
        shares = share(secret, 5, 9, rng)
        samples.append(({"t_share": 9.0}, time.perf_counter() - t0))
        t0 = time.perf_counter()
        reconstruct(shares[:5])
        samples.append(({"t_share": 5.0}, time.perf_counter() - t0))
    W = 1 << 16
    key = np.array([0x5AFE, 0xB04E], np.uint32)
    for i in range(reps):
        t0 = time.perf_counter()
        keystream_pair_lanes_np(key, W, i * W)
        samples.append(({"t_prf_word": float(W)}, time.perf_counter() - t0))
    a = np.arange(W, dtype=np.uint32)
    for _ in range(reps):
        t0 = time.perf_counter()
        np.add(a, a)
        samples.append(({"t_add_elem": float(W)}, time.perf_counter() - t0))
    # the wire's "key agreement" is the toy seed draw of bon_secrets (the
    # §14 fidelity note), not an RSA keygen — measure what this
    # implementation pays so the fitted model predicts *this* system
    # rather than inheriting EDGE's 100 ms RSA constant
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(64):
            rng.getrandbits(64)
        samples.append(({"t_keyagree": 64.0}, time.perf_counter() - t0))
    st = np.random.RandomState(5)
    for _ in range(reps):
        t0 = time.perf_counter()
        st.randint(0, 1 << 32, W, dtype=np.uint64)
        samples.append(({"t_rng_word": float(W)}, time.perf_counter() - t0))


def run() -> dict:
    from repro.core.bon_protocol import run_bon_round
    from repro.core.costs import EDGE, CostModel
    from repro.core.protocol import run_safe_round
    from repro.net.faults import WAN_PROFILES, make_wan_interceptor
    from repro.net.loadgen import run_bon_scale, run_paper_scale

    out: dict = {"cpu_count": os.cpu_count() or 1}

    # ---- head-to-head on clean localhost transport --------------------
    sizes = (8,) if SMOKE else (8, 36, 128)
    big_kw = dict(progress_timeout=2.0, monitor_interval=0.5)
    for n in sizes:
        f = FAILED if n > 8 else (2, 7)
        safe_kw = big_kw if n >= 128 else {}
        out[f"safe_n{n}"] = asyncio.run(
            run_paper_scale(n=n, V=256, **safe_kw))
        out[f"safe_n{n}_f{len(f)}"] = asyncio.run(
            run_paper_scale(n=n, V=256, failures=f, **safe_kw))
        out[f"bon_n{n}"] = asyncio.run(run_bon_scale(n=n, V=256))
        if n < 128:  # BON failover at n=128 adds ~1 min of unmask RPCs
            out[f"bon_n{n}_f{len(f)}"] = asyncio.run(
                run_bon_scale(n=n, V=256, failures=f))
    for key in sorted(k for k in out if k.startswith(("safe_n", "bon_n"))):
        _emit_row(key, out[key])

    # ---- the same pair under calibrated WAN profiles ------------------
    out["wan"] = {}
    for profile in WAN_PROFILES_RUN:
        meta = WAN_PROFILES[profile]
        rtt = meta["rtt_ms"] / 1e3
        # a slow WAN hop must not trip the §5.3 monitor or a long-poll
        # deadline: scale both by the nominal RTT (tail profiles run
        # several RTTs beyond nominal on p99 draws)
        wan_kw = dict(timeout_scale=max(1.0, 60.0 * rtt),
                      aggregation_timeout=240.0)
        safe = asyncio.run(run_paper_scale(
            n=WAN_N, V=256, interceptor=make_wan_interceptor(profile, seed=1),
            progress_timeout=max(0.3, 30.0 * rtt),
            monitor_interval=max(0.1, 5.0 * rtt), **wan_kw))
        # the roster settles the moment all n masked inputs arrive, so a
        # generous timeout costs a clean round nothing — but a short one
        # misdeclares live nodes dropped when WAN draws spread the n
        # posts beyond it (each node's serial R0/R1 chain is ~2n RPCs of
        # latency draws, so the spread grows with both n and RTT)
        bon = asyncio.run(run_bon_scale(
            n=WAN_N, V=256, interceptor=make_wan_interceptor(profile, seed=2),
            roster_timeout=max(5.0, 100.0 * rtt), **wan_kw))
        row = {"profile": profile, "rtt_ms": meta["rtt_ms"],
               "loss": meta["loss"], "kind": meta["kind"],
               "cpu_count": out["cpu_count"],
               "safe": safe, "bon": bon,
               "wall_ratio": bon["wall_s"] / safe["wall_s"]}
        out["wan"][profile] = row
        emit(f"bon_wire/wan_{profile}", safe["wall_s"] * 1e6,
             f"rtt={meta['rtt_ms']:.0f}ms loss={meta['loss']} "
             f"kind={meta['kind']} cpus={out['cpu_count']} "
             f"safe={safe['wall_s']:.2f}s bon={bon['wall_s']:.2f}s "
             f"bon/safe x{row['wall_ratio']:.1f}")

    # ---- calibrate the cost model from this host's micro-latencies ----
    samples: list = []
    asyncio.run(_measure_rpc(samples))
    _measure_compute(samples)
    fitted, resid = CostModel.fit(samples, base=EDGE, name="localhost_fit")
    out["fit"] = {
        "constants": {k: getattr(fitted, k) for k in
                      ("t_msg", "t_byte", "t_share", "t_prf_word",
                       "t_add_elem", "t_keyagree", "t_rng_word")},
        "residuals": resid,
        "n_samples": len(samples),
    }
    emit("bon_wire/fit", fitted.t_msg * 1e6,
         f"t_msg={fitted.t_msg:.2e}s t_byte={fitted.t_byte:.2e}s "
         f"t_share={fitted.t_share:.2e}s rms={resid['rms']:.2e} "
         f"r2={resid['r2']:.4f}")

    # ---- §6.1 ratio, three ways: measured wire wall-clock, the fitted
    # model's virtual time, and the stock EDGE model ---------------------
    n_ratio = 8 if SMOKE else 36
    f_ratio = (2, 7) if SMOKE else FAILED
    rng = np.random.RandomState(0)
    vals = rng.uniform(-1, 1, (n_ratio, 256)).astype(np.float32)
    ratios: dict = {}
    for label, model in (("fitted_model", fitted), ("edge_model", EDGE)):
        s = run_safe_round(vals, cost=model)
        s_f = run_safe_round(vals, failed_nodes=list(f_ratio), cost=model)
        b = run_bon_round(vals, cost=model)
        b_f = run_bon_round(vals, failed_nodes=list(f_ratio), cost=model)
        ratios[label] = {
            "time_clean": b.virtual_time / s.virtual_time,
            "time_failover": b_f.virtual_time / s_f.virtual_time,
        }
    fk = f"f{len(f_ratio)}"
    ratios["measured_wire"] = {
        "time_clean": (out[f"bon_n{n_ratio}"]["wall_s"]
                       / out[f"safe_n{n_ratio}"]["wall_s"]),
        "time_failover": (out[f"bon_n{n_ratio}_{fk}"]["wall_s"]
                          / out[f"safe_n{n_ratio}_{fk}"]["wall_s"]),
        "messages_clean": (out[f"bon_n{n_ratio}"]["messages"]
                           / out[f"safe_n{n_ratio}"]["messages"]),
    }
    out["ratios_61"] = ratios
    emit("bon_wire/ratio_61", ratios["measured_wire"]["time_clean"] * 1e6,
         f"n={n_ratio} bon/safe measured x"
         f"{ratios['measured_wire']['time_clean']:.1f} clean, fitted model "
         f"x{ratios['fitted_model']['time_clean']:.1f}, edge model "
         f"x{ratios['edge_model']['time_clean']:.1f}; msgs x"
         f"{ratios['measured_wire']['messages_clean']:.1f}")
    save_json("bon_wire", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("bon_wire", run)
