"""Multi-session engine throughput: rounds/sec vs. concurrent sessions.

Measures the batched AggregationEngine (S sessions through ONE compiled
shard_map program per step) against the unbatched loop (S separate
single-session aggregate calls) at S ∈ {1, 8, 32}, on an 8-host-device
mesh in a subprocess. The batched path amortizes program dispatch and
shares one ppermute schedule across sessions; the acceptance bar is
>2x rounds/sec at S=32.
"""
from __future__ import annotations

from benchmarks.common import (emit, run_device_subprocess, save_json,
                               standalone_bench)

_CODE = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import ChainConfig, SecureAggregator
from repro.serve import AggregationEngine

mesh = jax.make_mesh((8,), ("data",))
n, V = 8, 4096
rng = np.random.RandomState(0)
cfg = ChainConfig(num_learners=n, mode="safe")

# ---- unbatched baseline: one jitted single-session program ------------
single = SecureAggregator(cfg)
def per_rank(v, ctr):
    return single.aggregate(v.reshape(-1), ctr)
shard_fn = jax.shard_map(per_rank, mesh=mesh, in_specs=(P("data"), P()),
                         out_specs=P(), axis_names=frozenset({"data"}),
                         check_vma=False)
single_fn = jax.jit(shard_fn)

def unbatched_rounds(vals_list, ctrs):
    outs = []
    with jax.set_mesh(mesh):
        for v, c in zip(vals_list, ctrs):
            outs.append(single_fn(v, c))
    return jax.block_until_ready(outs)

out = {}
for S in (1, 8, 32):
    vals = [jnp.asarray(rng.uniform(-1, 1, (n, V)).astype(np.float32))
            for _ in range(S)]
    ctrs = [jnp.asarray(np.uint32(s * V)) for s in range(S)]
    unbatched_rounds(vals, ctrs)  # compile + warm
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        unbatched_rounds(vals, ctrs)
    t_un = (time.perf_counter() - t0) / reps

    eng = AggregationEngine(mesh, cfg, slots=S, payload_words=V)
    npvals = [np.asarray(v) for v in vals]
    for v in npvals:
        eng.submit(v)
    eng.step()  # compile + warm (one full round for every session)
    t0 = time.perf_counter()
    for _ in range(reps):
        for v in npvals:
            eng.submit(v)
        eng.step()
    t_b = (time.perf_counter() - t0) / reps

    out[str(S)] = {
        "sessions": S,
        "unbatched_wall_s": t_un,
        "batched_wall_s": t_b,
        "unbatched_rounds_per_s": S / t_un,
        "batched_rounds_per_s": S / t_b,
        "speedup": t_un / t_b,
    }
print("JSON" + json.dumps(out))
"""


def run() -> dict:
    payload = run_device_subprocess(_CODE)
    for S, row in payload.items():
        emit(f"multi_session/S{S}_batched", row["batched_wall_s"] * 1e6,
             f"rps={row['batched_rounds_per_s']:.1f} "
             f"speedup={row['speedup']:.2f}x")
        emit(f"multi_session/S{S}_unbatched", row["unbatched_wall_s"] * 1e6,
             f"rps={row['unbatched_rounds_per_s']:.1f}")
    save_json("multi_session", payload)
    return payload


def main():
    run()


if __name__ == "__main__":
    # standalone runs also emit BENCH_multi_session.json (stable
    # safe-bench/v1 schema), not just the legacy multi_session.json
    standalone_bench("multi_session", run)
