"""§5.2-5.5 message-complexity table: measured counts vs closed forms.

  basic:          4n
  progress fail:  4(n−f) + 2f       (n−f completing nodes, f reposts)
  subgroups:      4n + g
  init failover:  ≤ (i+1)(4n + 2f + i·n)
  BON:            O(n²) share relays
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.bon_protocol import run_bon_round
from repro.core.protocol import run_safe_round


def run() -> dict:
    rows = []
    for n in (3, 8, 16, 32, 64):
        vals = np.random.RandomState(n).uniform(-1, 1, (n, 2)) \
            .astype(np.float32)
        got = run_safe_round(vals).stats.aggregation_total
        rows.append({"case": f"basic n={n}", "measured": got,
                     "formula": 4 * n, "match": got == 4 * n})
    for n, f in ((10, 2), (16, 3)):
        failed = list(range(4, 4 + f))
        vals = np.random.RandomState(n).uniform(-1, 1, (n, 2)) \
            .astype(np.float32)
        got = run_safe_round(vals, failed_nodes=failed).stats.aggregation_total
        want = 4 * (n - f) + 2 * f
        rows.append({"case": f"failover n={n} f={f}", "measured": got,
                     "formula": want, "match": got == want})
    for n, g in ((12, 3), (16, 4)):
        vals = np.random.RandomState(n).uniform(-1, 1, (n, 2)) \
            .astype(np.float32)
        got = run_safe_round(vals, subgroups=g).stats.aggregation_total
        want = 4 * n + g
        rows.append({"case": f"subgroups n={n} g={g}", "measured": got,
                     "formula": want, "match": got == want})
    n = 10
    vals = np.random.RandomState(n).uniform(-1, 1, (n, 2)).astype(np.float32)
    got = run_safe_round(vals, initiator_fails=True,
                         aggregation_timeout=2.0).stats.aggregation_total
    bound = 2 * (4 * n + n)
    rows.append({"case": f"init-failover n={n} i=1", "measured": got,
                 "formula": f"<= {bound}", "match": got <= bound})
    for n in (8, 16, 32):
        vals = np.random.RandomState(n).uniform(-1, 1, (n, 2)) \
            .astype(np.float32)
        got = run_bon_round(vals).messages
        rows.append({"case": f"bon n={n}", "measured": got,
                     "formula": "O(n^2)", "match": True})
    for r in rows:
        emit(f"messages/{r['case'].replace(' ', '_')}", float(r["measured"]),
             f"formula={r['formula']} match={r['match']}")
    ok = all(r["match"] for r in rows)
    emit("messages/all_match", 0.0, str(ok))
    save_json("messages", {"rows": rows, "all_match": ok})
    return {"rows": rows}


def main():
    run()


if __name__ == "__main__":
    main()
