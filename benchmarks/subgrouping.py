"""Figures 19-20: subgrouping speedup (deep-edge, 12 learners).

Groupings 1×12, 2×6, 3×4, 4×3 at 1 and 20 features — parallel chains
with the controller averaging the (already anonymized) group averages.
Paper: ~4.5 s -> ~2 s with four groups at 1 feature.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.costs import DEEP_EDGE
from repro.core.protocol import run_safe_round

GROUPS = (1, 2, 3, 4)


def run() -> dict:
    out = {"groups": list(GROUPS), "series": {}}
    for V in (1, 20):
        ts, msgs = [], []
        vals = np.random.RandomState(V).uniform(-1, 1, (12, V)) \
            .astype(np.float32)
        for g in GROUPS:
            r = run_safe_round(vals, subgroups=g, cost=DEEP_EDGE,
                               symmetric_only=True)
            ts.append(r.virtual_time)
            msgs.append(r.stats.aggregation_total)
        out["series"][f"f{V}"] = {"virtual_s": ts, "messages": msgs}
        emit(f"fig19-20/f{V}", ts[-1] * 1e6,
             f"g1={ts[0]:.2f}s g4={ts[-1]:.2f}s speedup={ts[0]/ts[-1]:.2f}x")
    save_json("subgrouping", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
