"""Wire-plane load: S concurrent tenant sessions against one broker.

Two measurements over real localhost TCP (in an 8-host-device
subprocess, like the other mesh benchmarks):

  * engine plane — tenants submit whole sessions through
    ``submit_session``/``wait_session``; the broker batches them into
    one ``AggregationEngine`` compiled program per step. Reported:
    rounds/sec + p50/p99 submit→published latency at S ∈ {4, 16}.
  * protocol plane — each tenant runs full 8-learner SAFE rounds (one
    TCP connection per learner, 4n RPCs + long-polls per round)
    concurrently, at S ∈ {1, 4}; also once under a lossy/slow transport
    (latency + drop interceptors) to price fault handling.

Rows land in the standard CSV/JSON harness; `python -m benchmarks.run
--bench-json` (or a standalone run) also writes BENCH_net_load.json.
"""
from __future__ import annotations

from benchmarks.common import (emit, run_device_subprocess, save_json,
                               standalone_bench)

_CODE = """
import asyncio, json, time
import numpy as np, jax
from repro.core.types import ChainConfig
from repro.serve import AggregationEngine
from repro.net import SafeBroker, LatencyInterceptor, DropInterceptor, Chain
from repro.net.loadgen import run_engine_load, run_protocol_load

out = {}

async def engine_plane():
    mesh = jax.make_mesh((8,), ("data",))
    n, V = 8, 1024
    for S in (4, 16):
        cfg = ChainConfig(num_learners=n, mode="safe")
        engine = AggregationEngine(mesh, cfg, slots=S, payload_words=V)
        broker = SafeBroker(engine=engine)
        addr = await broker.start()
        try:
            rep = await run_engine_load(addr, tenants=S,
                                        rounds_per_tenant=8, n=n, V=V)
        finally:
            await broker.stop()
        out[f"engine_S{S}"] = rep.row()

async def protocol_plane():
    for S in (1, 4):
        broker = SafeBroker(progress_timeout=0.5, monitor_interval=0.1,
                            aggregation_timeout=60.0)
        addr = await broker.start()
        try:
            rep = await run_protocol_load(addr, tenants=S,
                                          rounds_per_tenant=3, n=8, V=256)
        finally:
            await broker.stop()
        out[f"protocol_S{S}"] = rep.row()
    # lossy/slow transport: what §5.3-ready transport handling costs
    broker = SafeBroker(progress_timeout=0.5, monitor_interval=0.1,
                        aggregation_timeout=60.0)
    addr = await broker.start()
    try:
        # factory form: per-tenant interceptors, reproducible fault plans
        ic = lambda t: Chain(LatencyInterceptor(mean=0.002, seed=1 + 2 * t),
                             DropInterceptor(p=0.02, seed=2 + 2 * t))
        rep = await run_protocol_load(addr, tenants=2, rounds_per_tenant=2,
                                      n=8, V=256, interceptor=ic)
    finally:
        await broker.stop()
    out["protocol_S2_faulty"] = rep.row()

asyncio.run(engine_plane())
asyncio.run(protocol_plane())
print("JSON" + json.dumps(out))
"""


def run() -> dict:
    payload = run_device_subprocess(_CODE)
    for key, row in payload.items():
        emit(f"net_load/{key}", row["p50_s"] * 1e6,
             f"rps={row['rounds_per_s']:.1f} "
             f"p99={row['p99_s']*1e3:.1f}ms tenants={row['tenants']}")
    save_json("net_load", payload)
    return payload


def main():
    run()


if __name__ == "__main__":
    standalone_bench("net_load", run)
