"""Wire-plane load: S concurrent tenant sessions against one broker.

Three measurements over real localhost TCP:

  * engine plane — tenants submit whole sessions through
    ``submit_session``/``wait_session``; the broker batches them into
    one ``AggregationEngine`` compiled program per step (8-host-device
    subprocess, like the other mesh benchmarks). Reported: rounds/sec +
    p50/p99 submit→published latency at S ∈ {4, 16}.
  * protocol plane — each tenant runs full 8-learner SAFE rounds (one
    TCP connection per learner, 4n RPCs + long-polls per round)
    concurrently, at S ∈ {1, 4}; also once under a lossy/slow transport
    (latency + drop interceptors) to price fault handling.
  * scaling — the ISSUE 6 curve: protocol-plane rounds/s and p99 at
    S = 8 tenants against shards ∈ {1, 2, 4}
    (:class:`~repro.net.shard.ShardedBroker` worker processes behind
    one SO_REUSEPORT port), with the *client* side spread over worker
    processes too (``client_procs``) so the measured ceiling is the
    broker, not the load generator. ``host_cpus`` rides along in the
    payload: process sharding can only buy wall-clock where cores
    exist, so trajectory tooling must read the curve relative to it
    (a 1-core box measures ≈ flat — that is the honest number there).

``SAFE_SMOKE=1`` skips the jax engine subprocess and shrinks the
protocol/scaling shapes for CI. Rows land in the standard CSV/JSON
harness; `python -m benchmarks.run --bench-json` (or a standalone run)
also writes BENCH_net_load.json.
"""
from __future__ import annotations

import asyncio
import os

from benchmarks.common import (emit, run_device_subprocess, save_json,
                               standalone_bench)

SMOKE = bool(os.environ.get("SAFE_SMOKE"))
SHARD_COUNTS = (1, 2) if SMOKE else (1, 2, 4)
SCALE_TENANTS = 4 if SMOKE else 8
SCALE_ROUNDS = 2 if SMOKE else 4
SCALE_CLIENT_PROCS = max(SHARD_COUNTS)

_CODE = """
import asyncio, json, time
import numpy as np, jax
from repro.core.types import ChainConfig
from repro.serve import AggregationEngine
from repro.net import SafeBroker, LatencyInterceptor, DropInterceptor, Chain
from repro.net.loadgen import run_engine_load, run_protocol_load

out = {}

async def engine_plane():
    mesh = jax.make_mesh((8,), ("data",))
    n, V = 8, 1024
    for S in (4, 16):
        cfg = ChainConfig(num_learners=n, mode="safe")
        engine = AggregationEngine(mesh, cfg, slots=S, payload_words=V)
        broker = SafeBroker(engine=engine)
        addr = await broker.start()
        try:
            rep = await run_engine_load(addr, tenants=S,
                                        rounds_per_tenant=8, n=n, V=V)
        finally:
            await broker.stop()
        out[f"engine_S{S}"] = rep.row()

asyncio.run(engine_plane())
print("JSON" + json.dumps(out))
"""


async def _protocol_plane(out: dict) -> None:
    from repro.net import (Chain, DropInterceptor, LatencyInterceptor,
                           SafeBroker)
    from repro.net.loadgen import run_protocol_load

    broker_kw = dict(progress_timeout=0.5, monitor_interval=0.1,
                     aggregation_timeout=60.0)
    for S in (1, 4):
        broker = SafeBroker(**broker_kw)
        addr = await broker.start()
        try:
            rep = await run_protocol_load(addr, tenants=S,
                                          rounds_per_tenant=3, n=8, V=256)
        finally:
            await broker.stop()
        out[f"protocol_S{S}"] = rep.row()
    # lossy/slow transport: what §5.3-ready transport handling costs
    broker = SafeBroker(**broker_kw)
    addr = await broker.start()
    try:
        # factory form: per-tenant interceptors, reproducible fault plans
        ic = lambda t: Chain(  # noqa: E731
            LatencyInterceptor(mean=0.002, seed=1 + 2 * t),
            DropInterceptor(p=0.02, seed=2 + 2 * t))
        rep = await run_protocol_load(addr, tenants=2, rounds_per_tenant=2,
                                      n=8, V=256, interceptor=ic)
    finally:
        await broker.stop()
    out["protocol_S2_faulty"] = rep.row()


def _host_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


async def _scaling(out: dict) -> None:
    """Rounds/s and p99 vs shard count at fixed tenant load; the client
    side uses the same ``client_procs`` for EVERY row so the only
    variable along the curve is the broker topology."""
    from repro.net import SafeBroker, ShardedBroker
    from repro.net.loadgen import run_protocol_load

    broker_kw = dict(progress_timeout=0.5, monitor_interval=0.1,
                     aggregation_timeout=60.0)
    scaling: dict = {
        "host_cpus": _host_cpus(),
        "tenants": SCALE_TENANTS,
        "rounds_per_tenant": SCALE_ROUNDS,
        "client_procs": SCALE_CLIENT_PROCS,
    }
    rps: dict = {}
    for shards in SHARD_COUNTS:
        if shards > 1:
            broker = ShardedBroker(shards, **broker_kw)
        else:
            broker = SafeBroker(**broker_kw)
        addr = await broker.start()
        try:
            # warm pass (connections, key derivation, spawn caches) then
            # best of two measured passes — localhost wall jitter
            await run_protocol_load(
                addr, tenants=SCALE_TENANTS, rounds_per_tenant=1,
                n=8, V=256, client_procs=SCALE_CLIENT_PROCS)
            reps = []
            for _ in range(2):
                reps.append(await run_protocol_load(
                    addr, tenants=SCALE_TENANTS,
                    rounds_per_tenant=SCALE_ROUNDS, n=8, V=256,
                    client_procs=SCALE_CLIENT_PROCS))
            rep = max(reps, key=lambda r: r.rounds_per_s)
        finally:
            await broker.stop()
        row = dict(rep.row(), shards=shards)
        scaling[f"shards{shards}"] = row
        rps[shards] = rep.rounds_per_s
        out[f"scaling_shards{shards}"] = row
    for shards in SHARD_COUNTS[1:]:
        scaling[f"speedup_{shards}x"] = rps[shards] / rps[1]
    out["scaling"] = scaling


def run() -> dict:
    out: dict = {}
    if SMOKE:
        out["engine_skipped"] = "SAFE_SMOKE"
    else:
        out.update(run_device_subprocess(_CODE))
    asyncio.run(_protocol_plane(out))
    asyncio.run(_scaling(out))
    for key, row in out.items():
        if not isinstance(row, dict) or "p50_s" not in row:
            continue
        extra = f" shards={row['shards']}" if "shards" in row else ""
        emit(f"net_load/{key}", row["p50_s"] * 1e6,
             f"rps={row['rounds_per_s']:.1f} "
             f"p99={row['p99_s']*1e3:.1f}ms tenants={row['tenants']}"
             f"{extra}")
    sc = out["scaling"]
    curve = " ".join(
        f"S{s}={sc[f'shards{s}']['rounds_per_s']:.1f}"
        for s in SHARD_COUNTS)
    emit("net_load/scaling", sc[f"shards{SHARD_COUNTS[-1]}"]["p99_s"] * 1e6,
         f"rounds/s {curve} cpus={sc['host_cpus']}")
    save_json("net_load", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("net_load", run)
