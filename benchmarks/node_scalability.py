"""Figures 6-9: aggregation time vs number of nodes.

INSEC / SAF / SAFE (+BON up to its practical limit) on the edge cost
model; both 1 feature (Figs. 6-7) and 10000 features (Figs. 8-9).
Reported: simulated protocol time (the paper's y-axis) and host wall
time of the real masked arithmetic.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, wall
from repro.core.bon_protocol import run_bon_round
from repro.core.protocol import run_safe_round


def run(features: int = 1, max_nodes: int = 100, bon_max: int = 15,
        repeats: int = 3) -> dict:
    nodes = [n for n in (3, 5, 8, 10, 15, 24, 36, 50, 75, 100)
             if n <= max_nodes]
    out = {"features": features, "nodes": nodes, "series": {}}
    for mode in ("insec", "saf", "safe"):
        vtimes, wtimes = [], []
        for n in nodes:
            vals = np.random.RandomState(n).uniform(-1, 1, (n, features)) \
                .astype(np.float32)
            res = run_safe_round(vals, mode=mode)
            vtimes.append(res.virtual_time)
            wtimes.append(wall(lambda: run_safe_round(vals, mode=mode),
                               repeats))
        out["series"][mode] = {"virtual_s": vtimes, "wall_s": wtimes}
        emit(f"fig6-9/{mode}/n{nodes[-1]}/f{features}",
             vtimes[-1] * 1e6, f"virtual_s={vtimes[-1]:.4f}")
    bon_nodes = [n for n in nodes if n <= bon_max]
    vtimes = []
    for n in bon_nodes:
        vals = np.random.RandomState(n).uniform(-1, 1, (n, features)) \
            .astype(np.float32)
        vtimes.append(run_bon_round(vals).virtual_time)
    out["series"]["bon"] = {"nodes": bon_nodes, "virtual_s": vtimes}
    emit(f"fig6-9/bon/n{bon_nodes[-1]}/f{features}", vtimes[-1] * 1e6,
         f"virtual_s={vtimes[-1]:.4f}")
    # headline ratios (paper: SAFE ~3x INSEC, BON ~40x INSEC @15 nodes/1f)
    i15 = out["series"]["insec"]["virtual_s"][nodes.index(15)]
    s15 = out["series"]["safe"]["virtual_s"][nodes.index(15)]
    if 15 in bon_nodes:
        b15 = vtimes[bon_nodes.index(15)]
        out["ratios_at_15"] = {"safe_over_insec": s15 / i15,
                               "bon_over_insec": b15 / i15}
        emit(f"fig6/ratio15/f{features}", 0.0,
             f"safe/insec={s15/i15:.1f}x bon/insec={b15/i15:.1f}x")
    save_json(f"node_scalability_f{features}", out)
    return out


def main():
    run(features=1)
    run(features=10000, max_nodes=36, repeats=1)


if __name__ == "__main__":
    main()
