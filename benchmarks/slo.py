"""SLO-gated wire-plane load (ISSUE 7): the observability loop closed.

Three heavy-tailed multi-tenant profiles from
:func:`repro.net.loadgen.run_slo_load`, each polling the live
``get_metrics`` plane while it runs and evaluating its SLOs — p99
round latency, zero dropped sessions, bounded chunk backlog — into a
``passed`` flag CI asserts (a regression FAILS the gate, it doesn't
just drift a JSON number):

  * ``steady`` — uniform tenants, ample budget: the baseline; any
    ``busy`` here is itself an SLO failure.
  * ``heavy_tail`` — few huge tenants over the chunk plane among many
    small ones, default budget: the realistic federation shape.
  * ``busy_shed`` — the flooding scenario: heavy tenants against a
    one-chunk admission budget, so their parallel §5.5 group chains
    are ``busy``-shed and retry-after their way through, while the
    small tenants never see a rejection and every published average
    stays bit-identical to the sim (asserted inside the harness).

``shed_recovered_tenants`` (>= 1 required by CI) counts tenants that
were refused at least once and still finished every round — admission
control degrading the flooder, not its neighbors.

A fourth row, ``wan_continental`` (ISSUE 9), is the SLO *calibration*
gate: every tenant runs behind the 50 ms-RTT / 1 % loss
``WAN_PROFILES["continental"]`` emulation, and the declared p99 is
derived from first principles — a SAFE round's critical path is ~4n
sequential RPCs (§5), each paying ~one nominal RTT on average, with a
2x factor for exponential jitter and loss-retry backoff — so the row
fails if the harness cannot actually HOLD the latency it declares.

``SAFE_SMOKE=1`` shrinks tenant/round counts for CI. Rows land in the
standard harness; standalone runs also write BENCH_slo.json.
"""
from __future__ import annotations

import asyncio
import os

from benchmarks.common import emit, save_json, standalone_bench

SMOKE = bool(os.environ.get("SAFE_SMOKE"))
TENANTS = 3 if SMOKE else 6
ROUNDS = 2 if SMOKE else 3
N = 6           # minimum for the heavy tenants' two privacy-valid rings
V = 128 if SMOKE else 256
PROFILES = ("steady", "heavy_tail", "busy_shed")


def _wan_slo_p99_s() -> float:
    """Declared p99 for the WAN calibration row: nominal RTT × the §5
    critical-path depth (~4n sequential RPCs per round) × 2 for
    exponential jitter and the 1 % loss-retry backoff."""
    from repro.net.faults import WAN_PROFILES

    rtt_s = WAN_PROFILES["continental"]["rtt_ms"] / 1e3
    return rtt_s * (4 * N + 8) * 2.0


async def _rows(out: dict) -> None:
    from repro.net.loadgen import run_slo_load

    def _row(rep) -> dict:
        row = rep.row()
        # instrumentation cross-check: the broker's own metrics plane
        # counted exactly the rounds the clients completed
        row["broker_rounds_match"] = (
            rep.broker_rounds_completed == rep.rounds)
        if rep.error:
            row["error"] = rep.error
        return row

    for profile in PROFILES:
        rep = await run_slo_load(
            profile=profile, tenants=TENANTS, rounds_per_tenant=ROUNDS,
            n=N, V=V, slo_p99_s=60.0)
        out[profile] = _row(rep)
    # WAN calibration (ISSUE 9): uniform tenants behind the continental
    # profile, gated on the first-principles p99 — not a generous 60 s
    rep = await run_slo_load(
        profile="steady", tenants=TENANTS, rounds_per_tenant=ROUNDS,
        n=N, V=V, wan_profile="continental", wan_seed=7,
        slo_p99_s=_wan_slo_p99_s())
    out["wan_continental"] = _row(rep)


def run() -> dict:
    out: dict = {"tenants": TENANTS, "rounds_per_tenant": ROUNDS,
                 "n": N, "V": V}
    asyncio.run(_rows(out))
    gated = PROFILES + ("wan_continental",)
    out["slo_pass"] = all(
        out[p]["passed"] and out[p]["broker_rounds_match"]
        for p in gated)
    out["shed_recovered_tenants"] = out["busy_shed"]["shed_tenants"]
    for profile in gated:
        row = out[profile]
        emit(f"slo/{profile}", row["p50_s"] * 1e6,
             f"p99={row['p99_s']*1e3:.1f}ms rps={row['rounds_per_s']:.1f} "
             f"busy={row['busy_rejections']} shed={row['shed_tenants']} "
             f"backlog_peak={row['backlog_peak_bytes']} "
             f"passed={row['passed']}")
    emit("slo/gate", out["busy_shed"]["p99_s"] * 1e6,
         f"slo_pass={out['slo_pass']} "
         f"shed_recovered={out['shed_recovered_tenants']}")
    save_json("slo", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("slo", run)
