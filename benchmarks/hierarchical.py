"""§5.10 hierarchical federation: child orgs post anonymized group
averages to a parent — sim cost model AND the real wire plane.

Two layers:

  * cost-model comparison — one flat 24-learner chain vs. 2 child
    federations × 12 with a parent averaging the two (already
    anonymized) results: the paper's answer once subgrouping saturates
    a single coordinator.
  * wire rows — :func:`repro.net.loadgen.run_hierarchical_scale` runs
    the chain-of-chains over real TCP (parent broker + child broker,
    per-org sessions with upstream uplinks, docs/PROTOCOL.md §15) and
    asserts BOTH levels' closed forms in-harness: per surviving org
    ``4(n_g − f_g) + 2 f_g + 1``, parent ``hierarchy_total == 2(c − f)``,
    plus bit-identity of the parent average against
    ``run_hierarchical_round_sim`` (and, clean, the flat
    ``run_safe_round(subgroups=orgs)``).

Default rows run the paper-shaped n=36 as 3 orgs × 12 — clean, one
dead learner inside an org, and a whole org crashed (elided by the
parent like a dead learner) — plus a clean n=128 as 4 orgs × 32.
``SAFE_SMOKE=1`` swaps in CI-sized n=8 rows (2 orgs × 4, clean + one
org elided) so the smoke gate still exercises the elision path.

Measured numbers live in EXPERIMENTS.md §Hierarchical. A standalone
run (``python -m benchmarks.hierarchical``) writes
``BENCH_hierarchical.json`` (schema ``safe-bench/v1``).
"""
from __future__ import annotations

import asyncio
import os

import numpy as np

from benchmarks.common import emit, save_json, standalone_bench
from repro.core.costs import EDGE
from repro.core.protocol import run_safe_round

SMOKE = bool(os.environ.get("SAFE_SMOKE"))


def _emit_wire(key: str, row: dict) -> None:
    org_msgs = ",".join(f"{g}:{m}" for g, m in
                        sorted(row["org_messages"].items()))
    elided = (f" elided={row['elided_orgs']}" if row["elided_orgs"]
              else "")
    emit(f"hierarchical/{key}", row["wall_s"] * 1e6,
         f"orgs={row['orgs']} org_msgs=[{org_msgs}] "
         f"hier={row['hierarchy_messages']}"
         f"/{row['expected_hierarchy_messages']}{elided} "
         f"bit_identical={row['bit_identical']}")


def run() -> dict:
    from repro.net.loadgen import run_hierarchical_scale

    n, V = 24, 64
    vals = np.random.RandomState(0).uniform(-1, 1, (n, V)).astype(np.float32)

    flat = run_safe_round(vals, mode="safe")

    # two independent child federations run in parallel (separate
    # controllers — wall time is the max of the two)
    left = run_safe_round(vals[:12], mode="safe")
    right = run_safe_round(vals[12:], mode="safe")
    parent_avg = np.mean([left.average, right.average], axis=0)
    hier_time = max(left.virtual_time, right.virtual_time) + EDGE.message(4 * V)
    hier_msgs = (left.stats.aggregation_total + right.stats.aggregation_total
                 + 2)  # two child->parent posts

    err_flat = float(np.max(np.abs(flat.average - vals.mean(0))))
    err_hier = float(np.max(np.abs(parent_avg - vals.mean(0))))
    out = {
        "flat": {"virtual_s": flat.virtual_time,
                 "messages": flat.stats.aggregation_total, "err": err_flat},
        "hierarchical": {"virtual_s": hier_time, "messages": hier_msgs,
                         "err": err_hier},
        "speedup": flat.virtual_time / hier_time,
    }
    emit("hierarchical/flat_n24", flat.virtual_time * 1e6,
         f"msgs={flat.stats.aggregation_total}")
    emit("hierarchical/2x12", hier_time * 1e6,
         f"msgs={hier_msgs} speedup={out['speedup']:.2f}x err={err_hier:.1e}")

    # ---- wire plane (real TCP, closed forms asserted in-harness) ------
    if SMOKE:
        out["wire_2x4"] = asyncio.run(
            run_hierarchical_scale(n=8, orgs=2, V=64))
        out["wire_2x4_org_crash"] = asyncio.run(
            run_hierarchical_scale(n=8, orgs=2, V=64, failed_orgs=(1,)))
        wire_keys = ("wire_2x4", "wire_2x4_org_crash")
    else:
        out["wire_3x12"] = asyncio.run(
            run_hierarchical_scale(n=36, orgs=3, V=256))
        out["wire_3x12_f1"] = asyncio.run(
            run_hierarchical_scale(n=36, orgs=3, V=256, failed_nodes=(5,)))
        out["wire_3x12_org_crash"] = asyncio.run(
            run_hierarchical_scale(n=36, orgs=3, V=256, failed_orgs=(2,)))
        out["wire_4x32"] = asyncio.run(
            run_hierarchical_scale(n=128, orgs=4, V=256))
        wire_keys = ("wire_3x12", "wire_3x12_f1", "wire_3x12_org_crash",
                     "wire_4x32")
    for key in wire_keys:
        _emit_wire(key, out[key])

    save_json("hierarchical", out)
    return out


def main():
    run()


if __name__ == "__main__":
    standalone_bench("hierarchical", run)
