"""§5.10 hierarchical federation: child controllers post anonymized group
averages to a parent.

Compares one flat 24-learner chain against 2 child controllers × 12
learners with a parent averaging the two (already anonymized) results —
the paper's answer once subgrouping saturates a single coordinator.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.controller import Controller, HierarchicalController
from repro.core.costs import EDGE
from repro.core.protocol import run_safe_round


def run() -> dict:
    n, V = 24, 64
    vals = np.random.RandomState(0).uniform(-1, 1, (n, V)).astype(np.float32)

    flat = run_safe_round(vals, mode="safe")

    # two independent child federations run in parallel (separate
    # controllers — wall time is the max of the two)
    left = run_safe_round(vals[:12], mode="safe")
    right = run_safe_round(vals[12:], mode="safe")
    parent_avg = np.mean([left.average, right.average], axis=0)
    hier_time = max(left.virtual_time, right.virtual_time) + EDGE.message(4 * V)
    hier_msgs = (left.stats.aggregation_total + right.stats.aggregation_total
                 + 2)  # two child->parent posts

    err_flat = float(np.max(np.abs(flat.average - vals.mean(0))))
    err_hier = float(np.max(np.abs(parent_avg - vals.mean(0))))
    out = {
        "flat": {"virtual_s": flat.virtual_time,
                 "messages": flat.stats.aggregation_total, "err": err_flat},
        "hierarchical": {"virtual_s": hier_time, "messages": hier_msgs,
                         "err": err_hier},
        "speedup": flat.virtual_time / hier_time,
    }
    emit("hierarchical/flat_n24", flat.virtual_time * 1e6,
         f"msgs={flat.stats.aggregation_total}")
    emit("hierarchical/2x12", hier_time * 1e6,
         f"msgs={hier_msgs} speedup={out['speedup']:.2f}x err={err_hier:.1e}")
    save_json("hierarchical", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
