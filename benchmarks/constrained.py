"""Figures 15-18: deep-edge (OpenWrt-class) node and feature scalability.

Uses the deep-edge cost profile (slow crypto, heavyweight per-request
stack) with symmetric-key pre-negotiation (§5.8) exactly as the paper's
busybox implementation does. SAF and INSEC are the ported baselines; BON
was not implemented on this platform in the paper either.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.costs import DEEP_EDGE
from repro.core.protocol import run_safe_round


def run() -> dict:
    out = {"series": {}}
    # Figs. 15-16: node scalability at 1 and 20 features
    for V in (1, 20):
        per_mode = {}
        for mode in ("insec", "saf", "safe"):
            ts = []
            for n in (3, 6, 9, 12):
                vals = np.random.RandomState(n).uniform(-1, 1, (n, V)) \
                    .astype(np.float32)
                ts.append(run_safe_round(vals, mode=mode, cost=DEEP_EDGE,
                                         symmetric_only=True).virtual_time)
            per_mode[mode] = ts
            emit(f"fig15-16/{mode}/f{V}/n12", ts[-1] * 1e6,
                 f"virtual_s={ts[-1]:.2f}")
        out["series"][f"nodes_f{V}"] = per_mode
    # Figs. 17-18: feature scalability at 3 and 12 nodes
    for n in (3, 12):
        per_mode = {}
        for mode in ("insec", "saf", "safe"):
            ts = []
            for V in (1, 5, 10, 20, 50):
                vals = np.random.RandomState(V).uniform(-1, 1, (n, V)) \
                    .astype(np.float32)
                ts.append(run_safe_round(vals, mode=mode, cost=DEEP_EDGE,
                                         symmetric_only=True).virtual_time)
            per_mode[mode] = ts
        out["series"][f"features_n{n}"] = per_mode
        emit(f"fig17-18/n{n}", 0.0,
             f"safe_f50={per_mode['safe'][-1]:.2f}s")
    # paper headline: SAFE ~2x INSEC at 3 nodes, ~4.5x at 12 (1 feature)
    s = out["series"]["nodes_f1"]
    out["overhead_vs_insec"] = {
        "n3": s["safe"][0] / s["insec"][0],
        "n12": s["safe"][-1] / s["insec"][-1],
    }
    emit("fig15/overhead", 0.0,
         f"n3={out['overhead_vs_insec']['n3']:.1f}x "
         f"n12={out['overhead_vs_insec']['n12']:.1f}x")
    save_json("constrained", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
