"""Quickstart: SAFE-secured data-parallel training in ~40 lines.

Run (CPU, 8 host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import make_aggregator
from repro.data import make_federated_batches
from repro.models import Model
from repro.train import MetricsLogger, make_train_step


def main():
    # 4 learners (cross-org chain) × tensor parallelism. TP > 1 needs
    # partial-manual shard_map (jax >= 0.6); older stacks fall back to
    # TP = 1 — see ARCHITECTURE.md "Version compatibility".
    tp = 2 if jax.__version_info__ >= (0, 6, 0) else 1
    mesh = jax.make_mesh((4, tp), ("data", "model"))
    cfg = get_smoke_config("internlm2-1.8b")
    model = Model(cfg)

    # the paper's technique: gradients flow through the SAFE chain instead
    # of an all-reduce — swap "safe" for "insec"/"saf"/"bon" to ablate
    aggregator = make_aggregator("safe", num_learners=4, axis="data")

    bundle = make_train_step(model, aggregator, mesh, lr=3e-3)
    state = bundle.init_state_fn(model.init(jax.random.key(0)))
    stream = make_federated_batches(cfg, num_learners=4, batch_per_learner=2,
                                    seq_len=128)
    # each org's local dataset: 4 batches, trained over multiple epochs
    dataset = [jnp.asarray(stream.global_batch(i)["tokens"])
               for i in range(4)]
    log = MetricsLogger(print_every=5)
    steps = 6 if os.environ.get("SAFE_SMOKE") else 30
    for step in range(steps):
        state, metrics = bundle.step_fn(
            state, dataset[step % len(dataset)],
            counter=step * (bundle.padded_size + 2))
        log.log(step, loss=metrics["loss"], grad=metrics["grad_scale"])
    print("final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
