"""Control-plane walkthrough: the SAFE protocol message flow, §5.3 progress
failover, and §5.4 initiator failover — on the discrete-event simulation
with real masked payloads (runs anywhere, no devices needed).

Run: PYTHONPATH=src python examples/failover_demo.py
"""
import numpy as np

from repro.core.protocol import run_safe_round
from repro.core.bon_protocol import run_bon_round


def show(title, res, expected):
    err = float(np.max(np.abs(res.average - expected)))
    s = res.stats
    print(f"\n=== {title} ===")
    print(f"  average error vs ground truth : {err:.2e}")
    print(f"  messages: post={s.post_aggregate} check={s.check_aggregate} "
          f"get={s.get_aggregate} post_avg={s.post_average} "
          f"get_avg={s.get_average} should_init={s.should_initiate} "
          f"(total {s.aggregation_total})")
    print(f"  virtual time: {res.virtual_time:.3f}s   "
          f"reposts: {res.monitor_reposts}   "
          f"elections: {res.initiator_elections}")


def main():
    n, V = 8, 16
    vals = np.random.RandomState(0).uniform(-1, 1, (n, V)).astype(np.float32)

    res = run_safe_round(vals)
    show(f"basic round, n={n} (expect 4n = {4*n} messages)", res,
         vals.mean(0))

    res = run_safe_round(vals, failed_nodes=[4, 5])
    mask = np.ones(n, bool); mask[[3, 4]] = False
    show("progress failover: learners 4,5 dead (controller re-targets the "
         "chain)", res, vals[mask].mean(0))

    res = run_safe_round(vals, initiator_fails=True, aggregation_timeout=2.0)
    show("initiator failover: learner 1 crashes after posting (round "
         "restarts with a new initiator)", res, vals[1:].mean(0))

    res = run_safe_round(vals, subgroups=2)
    exp = (vals[:4].mean(0) + vals[4:].mean(0)) / 2
    show("subgrouped: two parallel chains, average of group averages", res,
         exp)

    w = np.array([100, 200, 1000, 50, 75, 300, 400, 20], np.float32)
    res = run_safe_round(vals, weights=w)
    show("weighted averaging (§5.6): dataset sizes stay private", res,
         np.average(vals, 0, weights=w))

    bon = run_bon_round(vals, failed_nodes=[4])
    mask = np.ones(n, bool); mask[3] = False
    print(f"\n=== BON baseline with one dropout ===")
    print(f"  average error: "
          f"{float(np.max(np.abs(bon.average - vals[mask].mean(0)))):.2e}")
    print(f"  messages: {bon.messages} (vs SAFE's "
          f"{4*(n-1)+2})  shares reconstructed: {bon.shares_reconstructed}")


if __name__ == "__main__":
    main()
