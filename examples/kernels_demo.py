"""The Pallas masking kernels, end to end: a 4-learner chain computed
entirely with the fused TPU kernels (interpret mode on CPU), verified
against the clear-text mean.

Run: PYTHONPATH=src python examples/kernels_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.crypto.fixedpoint import FixedPointCodec
from repro.crypto.prf import derive_pair_key, keystream_pair_lanes
from repro.kernels import chain_combine, mask_add


def main():
    n, V = 4, 10_000
    rng = np.random.RandomState(0)
    vals = [jnp.asarray(rng.uniform(-3, 3, V).astype(np.float32))
            for _ in range(n)]
    codec = FixedPointCodec(16)

    # Round 0 (out-of-band): pairwise hop keys + the initiator's secret
    seed = jnp.array([2024, 8, 13][:2], jnp.uint32)
    hop_keys = [derive_pair_key(seed, i, (i + 1) % n) for i in range(n)]
    r_key = jnp.array([0xDEAD, 0xBEEF], jnp.uint32)
    R = keystream_pair_lanes(r_key, V, 0)

    # learner 1 (initiator): fused encode+mask kernel, then add R
    cipher = mask_add(vals[0], hop_keys[0], 0) + R
    print(f"initiator posts {cipher.nbytes/1e6:.1f} MB ciphertext")

    # learners 2..n: ONE fused kernel per hop (decrypt+add+re-encrypt)
    for i in range(1, n):
        cipher = chain_combine(cipher, vals[i], hop_keys[i - 1], hop_keys[i], 0)
        print(f"learner {i+1} combined (kernel hop)")

    # back at the initiator: strip the last pad and R, divide
    total = cipher - keystream_pair_lanes(hop_keys[-1], V, 0) - R
    avg = codec.decode(total) / n

    truth = np.mean([np.asarray(v) for v in vals], axis=0)
    err = float(np.max(np.abs(np.asarray(avg) - truth)))
    print(f"max error vs clear-text mean: {err:.2e} "
          f"(fixed-point resolution {1/2**16:.1e})")
    assert err < 1e-3


if __name__ == "__main__":
    main()
