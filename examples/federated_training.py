"""Cross-organizational FedAvg with SAFE weighted delta aggregation.

Four organizations with non-IID data and *different dataset sizes* train
locally; model deltas are combined with the paper's §5.6 weighted
averaging (dataset sizes stay private) over the SAFE chain. Midway, one
organization drops out — the §5.3 failover path keeps training going on
the survivors.

Run:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/federated_training.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import make_aggregator
from repro.data import make_federated_batches
from repro.models import Model
from repro.train import make_federated_round

LOCAL_STEPS = 2
ROUNDS = 12
FAIL_AT = 6  # org #2 goes dark after this round


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    agg = make_aggregator("safe", 4, axis="data", weighted=True)
    bundle = make_federated_round(model, agg, mesh,
                                  local_steps=LOCAL_STEPS, local_lr=2e-3)
    stream = make_federated_batches(cfg, 4, 2, 128)
    params = model.init(jax.random.key(0))

    # per-org dataset sizes (the §5.6 weights — never revealed)
    weights = jnp.array([4000.0, 1000.0, 2500.0, 500.0])
    # each org's fixed local dataset (2 rounds' worth), revisited every round
    local_data = [
        np.stack([np.stack([stream.learner_batch(l, e * LOCAL_STEPS + k)
                            ["tokens"] for k in range(LOCAL_STEPS)])
                  for l in range(4)])
        for e in range(2)]
    for r in range(ROUNDS):
        toks = local_data[r % 2]
        alive = jnp.ones(4)
        if r >= FAIL_AT:
            alive = alive.at[2].set(0.0)  # org 2 dropped out
        params, m = bundle.round_fn(params, jnp.asarray(toks),
                                    weights=weights, counter=r * (1 << 22),
                                    alive=alive)
        tag = " (org 2 DOWN, failover active)" if r >= FAIL_AT else ""
        print(f"round {r:2d}: local_loss={float(m['local_loss']):.4f} "
              f"delta={float(m['delta_norm']):.3f}{tag}")


if __name__ == "__main__":
    main()
