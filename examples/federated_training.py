"""Cross-organizational federated training over the SAFE wire plane.

The paper's actual use case, end to end in one script: an asyncio
broker (the controller "reduced to a mere message broker"), four
organizations with non-IID data and *different dataset sizes* each
running real local FedAvg steps (standalone jit — no device mesh
required), and their model deltas travelling the encrypted SAFE chain
over real TCP, chunk-streamed because a delta is bigger than one wire
frame (docs/PROTOCOL.md §6). Averaging is the paper's §5.6 weighted
mean, so no org reveals its dataset size. Midway, one organization
goes dark — the §5.3 failover path keeps training going on the
survivors.

The published delta here is bit-identical to the in-SPMD
`train/federated.py` round for the same seeds (tests/test_train.py).

Run:
  PYTHONPATH=src python examples/federated_training.py
(SAFE_SMOKE=1 shrinks the run for CI.)
"""
import asyncio
import os

import numpy as np

SMOKE = bool(os.environ.get("SAFE_SMOKE"))

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.data import make_federated_batches  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.net import SafeBroker, run_federated_round_net  # noqa: E402
from repro.train import make_wire_federated  # noqa: E402

N_ORGS = 4
LOCAL_STEPS = 2
ROUNDS = 3 if SMOKE else 10
FAIL_AT = 2 if SMOKE else 5  # org #3 goes dark after this round
CHUNK_WORDS = 1 << 18  # stream deltas in 256k-word chunks


def main():
    cfg = get_smoke_config("internlm2-1.8b")
    model = Model(cfg)
    stream = make_federated_batches(cfg, N_ORGS, 2, 128)
    params = model.init(jax.random.key(0))

    # per-org dataset sizes (the §5.6 weights — never revealed)
    weights = np.array([4000.0, 1000.0, 2500.0, 500.0], np.float32)
    # each org's fixed private shard: LOCAL_STEPS microbatches per round
    org_tokens = {
        l + 1: np.stack([stream.learner_batch(l, k)["tokens"]
                         for k in range(LOCAL_STEPS)])
        for l in range(N_ORGS)}
    wf = make_wire_federated(model, org_tokens, local_steps=LOCAL_STEPS,
                             local_lr=2e-3)
    print(f"model delta: {wf.payload_words} words "
          f"({wf.payload_words * 4 / 1e6:.1f} MB/hop, "
          f"{-(-wf.payload_words // CHUNK_WORDS)} chunks)")

    async def train(params):
        broker = SafeBroker(progress_timeout=0.5, monitor_interval=0.1,
                            aggregation_timeout=60.0)
        addr = await broker.start()
        try:
            for r in range(ROUNDS):
                failed = (3,) if r >= FAIL_AT else ()
                params, res = await run_federated_round_net(
                    params, wf.local_fns, wf.apply_fn, addr,
                    weights=weights, counter=r * (wf.payload_words + 1),
                    failed_nodes=failed, chunk_words=CHUNK_WORDS)
                losses = [wf.last_losses[n] for n in sorted(wf.last_losses)
                          if n not in failed]
                tag = " (org 3 DOWN, failover active)" if failed else ""
                print(f"round {r:2d}: local_loss={np.mean(losses):.4f} "
                      f"delta={np.linalg.norm(res.average):.3f} "
                      f"msgs={res.stats['aggregation_total']} "
                      f"chunks={res.stats['chunk_frames_in']}"
                      f"/{res.stats['chunk_frames_out']}{tag}")
        finally:
            await broker.stop()
        return params

    asyncio.run(train(params))


if __name__ == "__main__":
    main()
