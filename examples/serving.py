"""Batched serving: continuous prefill+decode over fixed batch slots.

Run: PYTHONPATH=src python examples/serving.py
(add XLA_FLAGS=--xla_force_host_platform_device_count=8 to see it sharded;
SAFE_SMOKE=1 shrinks the run for CI)
"""
import os
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serve import ServeEngine
from repro.serve.engine import Request


def main():
    cfg = get_smoke_config("qwen3-14b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=4, max_seq=256,
                      temperature=0.8, seed=0)
    rng = np.random.RandomState(0)
    n_req, max_new = (3, 8) if os.environ.get("SAFE_SMOKE") else (10, 24)
    done = []
    t0 = time.time()
    for i in range(n_req):
        prompt = rng.randint(0, cfg.vocab, rng.randint(4, 24)).astype(np.int32)
        eng.submit(Request(rid=i, prompt=prompt, max_new=max_new))
    eng.run_until_done()
    dt = time.time() - t0
    print(f"served {n_req} requests in {dt:.1f}s "
          f"({n_req*max_new/dt:.1f} tok/s, {eng.steps} batched decode steps)")


if __name__ == "__main__":
    main()
